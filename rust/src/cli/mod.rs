//! From-scratch CLI argument parsing (no clap offline).
//!
//! Grammar: `lpdnn <subcommand> [--flag value]... [--switch]...`
//! Subcommands are free-form strings validated by `main.rs`; this module
//! provides the generic flag machinery + help rendering.

use std::collections::BTreeMap;

use crate::error::Context;
use crate::bail;

/// Parsed command line: subcommand + flags.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: String,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
    /// Flags the caller actually read (for unknown-flag detection).
    known: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse `argv[1..]`. Flags are `--name value`; switches are `--name`
    /// followed by another flag or end of input.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> crate::Result<Args> {
        let mut it = argv.into_iter().peekable();
        let subcommand = it.next().unwrap_or_else(|| "help".to_string());
        if subcommand.starts_with("--") {
            bail!("expected a subcommand before flags (got '{subcommand}')");
        }
        let mut flags = BTreeMap::new();
        let mut switches = Vec::new();
        while let Some(tok) = it.next() {
            let name = tok
                .strip_prefix("--")
                .with_context(|| format!("expected --flag, got '{tok}'"))?
                .to_string();
            if name.is_empty() {
                bail!("empty flag name");
            }
            match it.peek() {
                Some(v) if !v.starts_with("--") => {
                    flags.insert(name, it.next().unwrap());
                }
                _ => switches.push(name),
            }
        }
        Ok(Args { subcommand, flags, switches, known: Default::default() })
    }

    fn mark(&self, name: &str) {
        self.known.borrow_mut().push(name.to_string());
    }

    /// String flag with default.
    pub fn get(&self, name: &str, default: &str) -> String {
        self.mark(name);
        self.flags.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Optional string flag.
    pub fn get_opt(&self, name: &str) -> Option<String> {
        self.mark(name);
        self.flags.get(name).cloned()
    }

    /// Parsed numeric flag with default.
    pub fn get_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> crate::Result<T>
    where
        T::Err: std::fmt::Display,
    {
        self.mark(name);
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse::<T>()
                .map_err(|e| crate::err!("--{name} {v}: {e}")),
        }
    }

    /// Boolean switch (present or absent).
    pub fn has(&self, name: &str) -> bool {
        self.mark(name);
        self.switches.iter().any(|s| s == name)
    }

    /// After all reads: error on flags the command never consumed.
    pub fn finish(&self) -> crate::Result<()> {
        let known = self.known.borrow();
        for f in self.flags.keys() {
            if !known.iter().any(|k| k == f) {
                bail!("unknown flag --{f} for subcommand '{}'", self.subcommand);
            }
        }
        for s in &self.switches {
            if !known.iter().any(|k| k == s) {
                bail!("unknown switch --{s} for subcommand '{}'", self.subcommand);
            }
        }
        Ok(())
    }
}

/// Read the file named by `--<flag> <path>`, turning io failures into a
/// config error naming the flag and path (never a raw io panic).
pub fn read_file_arg(flag: &str, path: &str) -> crate::Result<String> {
    std::fs::read_to_string(path).map_err(|e| crate::err!("--{flag} {path}: {e}"))
}

/// Preflight that `--<flag> <path>` is writable *before* spending the
/// expensive work whose results it will receive (a training run, a
/// sweep). Probes by opening in create+append mode, which never
/// truncates an existing file; a missing file is created empty, exactly
/// as the eventual write would.
pub fn preflight_writable(flag: &str, path: &str) -> crate::Result<()> {
    std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)
        .map(|_| ())
        .map_err(|e| crate::err!("--{flag} {path}: not writable: {e}"))
}

/// [`preflight_writable`] for flags whose writes land on *derived*
/// paths (`sweep --loss-csv` suffixes the base path per point, so the
/// base path itself is never written): probe a representative derived
/// sibling `probe` in the same directory, but name the user's declared
/// `path` in the error. When the probe file did not exist before the
/// call it is removed again, so a passing preflight leaves no stray
/// empty file behind.
pub fn preflight_writable_probe(
    flag: &str,
    path: &str,
    probe: &std::path::Path,
) -> crate::Result<()> {
    let existed = probe.exists();
    std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(probe)
        .map_err(|e| crate::err!("--{flag} {path}: not writable: {e}"))?;
    if !existed {
        let _ = std::fs::remove_file(probe);
    }
    Ok(())
}

/// Write `contents` to the file named by `--<flag> <path>`, naming the
/// flag and path on failure.
pub fn write_file_arg(flag: &str, path: &str, contents: &str) -> crate::Result<()> {
    std::fs::write(path, contents).map_err(|e| crate::err!("--{flag} {path}: {e}"))
}

/// Render the top-level help text.
pub fn help() -> String {
    "\
lpdnn — Low Precision Arithmetic for Deep Learning (Courbariaux et al. 2014)

USAGE:
    lpdnn <subcommand> [flags]

SUBCOMMANDS:
    train       Train one experiment
                  --config <file.toml>   experiment config (or use flags:)
                  --backend native|pjrt  execution backend (default native;
                                         pjrt needs --features pjrt + artifacts)
                  --model pi_mlp|pi_mlp_wide|conv|conv32|pi_conv
                  --topology SPEC        explicit maxout topology
                                         (overrides --model; realized
                                         against the dataset's shape):
                                         builtin name, WIDTHxDEPTH,
                                         w1,w2,..., or conv stages
                                         cCH[kKSIZE][pPOOL],.../dense,
                                         optionally @kN — e.g. 128x3,
                                         256,128@k2, pi_conv,
                                         c32k5p2,c64k5p2/128x2@k2
                  --dataset digits|clusters|cifar_like|svhn_like
                  --arith float32|half|fixed|dynamic
                  --bits-comp N --bits-up N --int-bits N
                  --max-overflow-rate R --update-every N --warmup N
                  --steps N --seed N --lr R --dropout-input R --dropout-hidden R
                  --eval-every N --loss-csv <file> --verbose
                  --dp-workers N         data-parallel workers per train step
                                         (default LPDNN_DP_WORKERS or 1);
                                         bit-identical results at any N —
                                         purely a wall-clock knob
                  --save <ckpt.json>     write a versioned checkpoint of the
                                         trained model after the run (restores
                                         bit-exactly with infer/serve)
    eval        Evaluate a config's arithmetic on a fresh model (sanity)
    infer       Restore a checkpoint and re-run the test-set evaluation;
                fails unless the recomputed error matches the checkpoint's
                train-time eval bit-exactly
                  --load <ckpt.json>     checkpoint written by train --save
    serve       Serve batched quantized inference from a checkpoint with a
                built-in closed-loop load generator; prints and persists
                latency percentiles, throughput, and batch-fill stats
                  --load <ckpt.json>     checkpoint written by train --save
                  --requests N           total requests to issue (default 256)
                  --concurrency N        closed-loop producer threads (default 4)
                  --workers N            inference worker threads (default 2)
                  --max-batch N          batching cap per forward (default 32)
                  --max-wait-us N        batcher linger after the first
                                         queued request, µs (default 2000)
                  --queue-cap N          bounded request-queue depth (default 64)
                  --open-rate R          open-loop Poisson arrivals at R req/s
                                         instead of closed-loop producers;
                                         percentiles then include honest
                                         queueing delay (default 0 = closed)
                  --open-seed N          arrival-schedule seed (default 1)
                  --bench-json <file>    stats output (default BENCH_serve.json)
    sweep       Run a sweep: float32 baseline + points over one axis,
                fanned across a worker pool (rows are bit-identical at
                any --jobs value; results print normalized by baseline)
                  base config: same flags as train (--model, --dataset,
                  --arith, --steps, ...; without --steps/--config the
                  default budget honors LPDNN_BENCH_SCALE)
                  --axis arith|comp-bits|up-bits|int-bits|overflow-rate
                                         (default arith: half,fixed,dynamic
                                         vs the float32 baseline — Table 3)
                  --points v1,v2,...     sweep values (default per axis)
                  --jobs N               parallel workers (default 1)
                  --report out.json      write a SweepReport JSON document
                  --loss-csv base.csv    one loss curve per point,
                                         suffixed by label
                  --verbose
    datasets    Print the dataset overview (paper Table 2 analogue)
    formats     Print format definitions (paper Table 1) and examples
    artifacts   List compiled artifacts from the manifest (pjrt backend)
    help        This message

ENVIRONMENT:
    LPDNN_ARTIFACTS     artifacts directory (default: ./artifacts)
    LPDNN_BENCH_SCALE   scale factor for bench/sweep budgets (default 1.0)
    LPDNN_BACKEND       backend for the bench binaries (native|pjrt)
    LPDNN_JOBS          sweep worker pool size for the bench binaries
    LPDNN_THREADS       worker-thread cap for the native matmul kernels
    LPDNN_PAR_MATMUL    FLOP threshold for going parallel (default 2^20)
    LPDNN_DP_WORKERS    default data-parallel train workers (--dp-workers wins)
"
    .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(tokens: &[&str]) -> Args {
        Args::parse(tokens.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn parses_subcommand_flags_switches() {
        let a = parse(&["train", "--model", "pi_mlp", "--steps", "100", "--verbose"]);
        assert_eq!(a.subcommand, "train");
        assert_eq!(a.get("model", "x"), "pi_mlp");
        assert_eq!(a.get_parse("steps", 0usize).unwrap(), 100);
        assert!(a.has("verbose"));
        a.finish().unwrap();
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["train"]);
        assert_eq!(a.get("model", "pi_mlp"), "pi_mlp");
        assert_eq!(a.get_parse("steps", 42usize).unwrap(), 42);
        assert!(!a.has("verbose"));
    }

    #[test]
    fn unknown_flags_detected() {
        let a = parse(&["train", "--bogus", "1"]);
        let _ = a.get("model", "pi_mlp");
        assert!(a.finish().is_err());
    }

    #[test]
    fn bad_numeric_flag_is_clear_error() {
        let a = parse(&["train", "--steps", "many"]);
        let err = a.get_parse("steps", 0usize).unwrap_err();
        assert!(format!("{err}").contains("--steps"));
    }

    #[test]
    fn flags_before_subcommand_rejected() {
        assert!(Args::parse(["--model".to_string()]).is_err());
    }

    #[test]
    fn missing_subcommand_is_help() {
        let a = Args::parse(Vec::<String>::new()).unwrap();
        assert_eq!(a.subcommand, "help");
    }

    #[test]
    fn negative_numbers_are_values_not_flags() {
        let a = parse(&["train", "--int-bits", "-3"]);
        assert_eq!(a.get_parse("int-bits", 0i32).unwrap(), -3);
    }

    #[test]
    fn read_file_arg_names_the_flag_and_path() {
        let err = read_file_arg("load", "/no/such/lpdnn_ckpt.json").unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("--load"), "{msg}");
        assert!(msg.contains("/no/such/lpdnn_ckpt.json"), "{msg}");
    }

    #[test]
    fn preflight_writable_names_the_flag_and_keeps_contents() {
        let err = preflight_writable("save", "/no/such/dir/out.json").unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("--save"), "{msg}");
        assert!(msg.contains("not writable"), "{msg}");

        // The probe must never truncate an existing file.
        let path = std::env::temp_dir().join("lpdnn_test_cli_preflight.json");
        std::fs::write(&path, "keep me").unwrap();
        preflight_writable("save", path.to_str().unwrap()).unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "keep me");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn preflight_probe_covers_suffixed_paths_and_cleans_up() {
        // failure names the declared flag/path, not the probe sibling
        let err = preflight_writable_probe(
            "loss-csv",
            "/no/such/dir/loss.csv",
            std::path::Path::new("/no/such/dir/loss-preflight.csv"),
        )
        .unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("--loss-csv"), "{msg}");
        assert!(msg.contains("/no/such/dir/loss.csv"), "{msg}");

        // a passing probe removes the file it created...
        let probe = std::env::temp_dir().join("lpdnn_test_cli_probe-preflight.csv");
        let _ = std::fs::remove_file(&probe);
        preflight_writable_probe("loss-csv", "declared.csv", &probe).unwrap();
        assert!(!probe.exists(), "probe file must be cleaned up");

        // ...but never deletes or truncates one that already existed
        std::fs::write(&probe, "keep me").unwrap();
        preflight_writable_probe("loss-csv", "declared.csv", &probe).unwrap();
        assert_eq!(std::fs::read_to_string(&probe).unwrap(), "keep me");
        let _ = std::fs::remove_file(&probe);
    }

    #[test]
    fn write_file_arg_round_trips() {
        let path = std::env::temp_dir().join("lpdnn_test_cli_write.json");
        let p = path.to_str().unwrap();
        write_file_arg("bench-json", p, "{}\n").unwrap();
        assert_eq!(read_file_arg("bench-json", p).unwrap(), "{}\n");
        let _ = std::fs::remove_file(&path);
        let err = write_file_arg("bench-json", "/no/such/dir/b.json", "{}").unwrap_err();
        assert!(format!("{err}").contains("--bench-json"));
    }
}
