//! Bit-level IEEE binary16 (half precision) conversion — paper Table 1.
//!
//! The paper's float16 experiment stores every signal through a half
//! precision round-trip (1 sign + 5 exponent + 10 mantissa bits). The L2
//! graph does this with an f32→f16→f32 cast pair; this module is the
//! bit-exact host twin, implemented from scratch (no `half` crate in the
//! offline environment) with round-to-nearest-even, subnormal handling,
//! infinities and NaN — validated against the device path in the runtime
//! integration tests.

/// Convert f32 to the nearest binary16 bit pattern (round-to-nearest-even).
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let man = bits & 0x7F_FFFF;

    if exp == 0xFF {
        // Inf / NaN: preserve NaN-ness (quiet bit set), propagate Inf.
        return if man == 0 { sign | 0x7C00 } else { sign | 0x7E00 };
    }

    // Unbiased exponent; f32 bias 127, f16 bias 15.
    let unbiased = exp - 127;
    if unbiased > 15 {
        return sign | 0x7C00; // overflow → ±Inf
    }
    if unbiased >= -14 {
        // Normal f16. Keep 10 mantissa bits, round-to-nearest-even on the
        // 13 dropped bits.
        let e16 = (unbiased + 15) as u32;
        let m16 = man >> 13;
        let rest = man & 0x1FFF;
        let halfway = 0x1000;
        let mut out = ((e16 << 10) | m16) as u16;
        if rest > halfway || (rest == halfway && (m16 & 1) == 1) {
            out = out.wrapping_add(1); // may carry into exponent — correct
        }
        return sign | out;
    }
    // Subnormal f16 (or zero): value = man' * 2^-24.
    if unbiased < -25 {
        return sign; // rounds to ±0
    }
    // Implicit leading 1 becomes explicit; shift right by the deficit.
    let full = man | 0x80_0000;
    let shift = (-14 - unbiased) as u32 + 13;
    let m16 = full >> shift;
    let rest = full & ((1 << shift) - 1);
    let halfway = 1u32 << (shift - 1);
    let mut out = m16 as u16;
    if rest > halfway || (rest == halfway && (m16 & 1) == 1) {
        out = out.wrapping_add(1);
    }
    sign | out
}

/// Expand a binary16 bit pattern to f32 (exact).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h as u32) & 0x8000) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let man = (h & 0x3FF) as u32;
    let bits = if exp == 0x1F {
        // Inf / NaN
        sign | 0x7F80_0000 | (man << 13)
    } else if exp == 0 {
        if man == 0 {
            sign // ±0
        } else {
            // Subnormal: normalize into f32.
            let mut e = -14i32;
            let mut m = man;
            while m & 0x400 == 0 {
                m <<= 1;
                e -= 1;
            }
            m &= 0x3FF;
            sign | (((e + 127) as u32) << 23) | (m << 13)
        }
    } else {
        sign | ((exp + 127 - 15) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

/// The float16 simulation op: round-trip a value through half precision.
#[inline]
pub fn half_roundtrip(x: f32) -> f32 {
    f16_bits_to_f32(f32_to_f16_bits(x))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{forall, Gen};

    #[test]
    fn exact_small_integers() {
        for i in -2048..=2048 {
            let x = i as f32;
            assert_eq!(half_roundtrip(x), x, "i={i}"); // 11-bit significand
        }
    }

    #[test]
    fn known_bit_patterns() {
        assert_eq!(f32_to_f16_bits(0.0), 0x0000);
        assert_eq!(f32_to_f16_bits(-0.0), 0x8000);
        assert_eq!(f32_to_f16_bits(1.0), 0x3C00);
        assert_eq!(f32_to_f16_bits(-2.0), 0xC000);
        assert_eq!(f32_to_f16_bits(65504.0), 0x7BFF); // f16 max normal
        assert_eq!(f32_to_f16_bits(f32::INFINITY), 0x7C00);
        assert_eq!(f32_to_f16_bits(2.0f32.powi(-24)), 0x0001); // min subnormal
    }

    #[test]
    fn overflow_to_infinity() {
        assert_eq!(f32_to_f16_bits(65520.0), 0x7C00); // rounds past max → Inf
        assert_eq!(f32_to_f16_bits(1e9), 0x7C00);
        assert_eq!(f32_to_f16_bits(-1e9), 0xFC00);
    }

    #[test]
    fn nan_propagates() {
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
    }

    #[test]
    fn roundtrip_idempotent() {
        forall("f16 idempotent", |g: &mut Gen| {
            let x = g.f32_range(-1000.0, 1000.0);
            let once = half_roundtrip(x);
            assert_eq!(half_roundtrip(once).to_bits(), once.to_bits());
        });
    }

    #[test]
    fn relative_error_bounded_for_normals() {
        forall("f16 rel error", |g: &mut Gen| {
            let x = g.f32_range(-60000.0, 60000.0);
            if x.abs() >= 6.2e-5 {
                // normal range
                let r = half_roundtrip(x);
                let rel = ((r - x) / x).abs();
                assert!(rel <= 2f32.powi(-11) + 1e-7, "x={x} r={r} rel={rel}");
            }
        });
    }

    #[test]
    fn subnormal_absolute_error_bounded() {
        forall("f16 subnormal", |g: &mut Gen| {
            let x = g.f32_range(-6e-5, 6e-5);
            let r = half_roundtrip(x);
            assert!((r - x).abs() <= 2f32.powi(-25) + 1e-12, "x={x} r={r}");
        });
    }

    #[test]
    fn matches_numpy_spot_checks() {
        // Values checked against numpy float16 semantics.
        assert_eq!(half_roundtrip(0.1), 0.099975586);
        assert_eq!(half_roundtrip(3.141592), 3.140625);
        assert_eq!(half_roundtrip(1e-7), 1.1920929e-07); // subnormal grid
    }

    #[test]
    fn round_to_nearest_even_on_ties() {
        // 2049 is exactly between 2048 and 2050 in f16 → even (2048).
        assert_eq!(half_roundtrip(2049.0), 2048.0);
        // 2051 is between 2050 and 2052 → 2052 (even mantissa).
        assert_eq!(half_roundtrip(2051.0), 2052.0);
    }
}
