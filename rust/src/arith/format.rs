//! Fixed point format descriptors (paper section 4).
//!
//! A format is a signed `total_bits`-wide mantissa plus a power-of-two
//! scaling factor, described here by the position of the radix point:
//! `int_bits` magnitude bits sit left of the radix point (paper Figure 1
//! talks about "the radix point position after the i-th most significant
//! bit"). The runtime encoding shared with the compiled artifacts is the
//! pair `(step, maxv)`:
//!
//! ```text
//! step = 2^(int_bits - (total_bits - 1))   // value of one LSB
//! maxv = 2^int_bits                        // saturation magnitude
//! grid = { k·step : -maxv/step ≤ k ≤ maxv/step - 1 }   (2^total_bits points)
//! ```
//!
//! `step == 0` is the float32 passthrough sentinel used throughout the
//! stack (one compiled artifact serves float32, fixed and dynamic fixed
//! point — see DESIGN.md).

use std::fmt;

/// A concrete fixed point format: total width (including sign) and radix
/// point position. `int_bits` may be negative (all-fractional formats with
/// leading zero fraction bits) — the paper's gradient groups end up there.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct FixedFormat {
    /// Total bit-width including the sign bit. 0 encodes float32 passthrough.
    pub total_bits: i32,
    /// Number of magnitude bits left of the radix point.
    pub int_bits: i32,
}

impl FixedFormat {
    /// A `total_bits`-wide format with the radix point after bit `int_bits`.
    pub const fn new(total_bits: i32, int_bits: i32) -> Self {
        Self { total_bits, int_bits }
    }

    /// The float32 passthrough sentinel (`step() == 0`).
    pub const FLOAT32: FixedFormat = FixedFormat { total_bits: 0, int_bits: 0 };

    /// Is this the float32 passthrough?
    pub fn is_float32(&self) -> bool {
        self.total_bits == 0
    }

    /// Value of one least-significant bit (the quantization step).
    /// Computed in f64 then narrowed so that deeply fractional formats
    /// (large negative exponents) stay exact.
    pub fn step(&self) -> f32 {
        if self.is_float32() {
            0.0
        } else {
            2f64.powi(self.int_bits - (self.total_bits - 1)) as f32
        }
    }

    /// Saturation magnitude: representable range is `[-maxv, maxv - step]`.
    pub fn maxv(&self) -> f32 {
        if self.is_float32() {
            0.0
        } else {
            2f64.powi(self.int_bits) as f32
        }
    }

    /// Number of representable grid points (2^total_bits).
    pub fn levels(&self) -> f64 {
        2f64.powi(self.total_bits)
    }

    /// The same format with the scaling factor doubled (one more integer
    /// bit, one less fraction bit) — the dynamic controller's "grow" move.
    pub fn scale_up(&self) -> FixedFormat {
        FixedFormat::new(self.total_bits, self.int_bits + 1)
    }

    /// The same format with the scaling factor halved — the "shrink" move.
    pub fn scale_down(&self) -> FixedFormat {
        FixedFormat::new(self.total_bits, self.int_bits - 1)
    }
}

impl fmt::Display for FixedFormat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.is_float32() {
            write!(f, "float32")
        } else {
            write!(f, "Q{}.{}", self.int_bits, self.total_bits - 1 - self.int_bits)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_and_maxv_match_l2_formulas() {
        // Mirrors python compile/formats.py: step_for / maxv_for.
        let f = FixedFormat::new(10, 3);
        assert_eq!(f.step(), (2f64.powi(3 - 9)) as f32);
        assert_eq!(f.maxv(), 8.0);
        let g = FixedFormat::new(12, 0);
        assert_eq!(g.step(), (2f64.powi(-11)) as f32);
        assert_eq!(g.maxv(), 1.0);
    }

    #[test]
    fn float32_sentinel() {
        assert!(FixedFormat::FLOAT32.is_float32());
        assert_eq!(FixedFormat::FLOAT32.step(), 0.0);
        assert_eq!(format!("{}", FixedFormat::FLOAT32), "float32");
    }

    #[test]
    fn paper_radix_5_range_is_32() {
        // Paper section 9.2: radix point after the 5th MSB ⇒ range ≈ [-32, 32].
        let f = FixedFormat::new(20, 5);
        assert_eq!(f.maxv(), 32.0);
    }

    #[test]
    fn grid_point_count() {
        for bits in [2, 8, 10, 12, 20, 31] {
            let f = FixedFormat::new(bits, 2);
            let n = (2.0 * f.maxv() as f64) / f.step() as f64;
            assert!((n - f.levels()).abs() < 1e-6, "bits={bits}");
        }
    }

    #[test]
    fn negative_int_bits_formats() {
        // All-fractional formats (gradients live here late in training).
        let f = FixedFormat::new(10, -3);
        assert_eq!(f.maxv(), 0.125);
        assert!(f.step() > 0.0 && f.step() < f.maxv());
    }

    #[test]
    fn scale_up_down_roundtrip() {
        let f = FixedFormat::new(12, 2);
        assert_eq!(f.scale_up().scale_down(), f);
        assert_eq!(f.scale_up().maxv(), 2.0 * f.maxv());
        assert_eq!(f.scale_down().maxv(), 0.5 * f.maxv());
    }

    #[test]
    fn display_q_notation() {
        assert_eq!(format!("{}", FixedFormat::new(20, 5)), "Q5.14");
        assert_eq!(format!("{}", FixedFormat::new(10, -2)), "Q-2.11");
    }
}
