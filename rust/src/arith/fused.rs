//! The quantization epilogue of the fused quantize-aware GEMM kernels.
//!
//! The paper's model re-quantizes every matmul output immediately (every
//! multiplication result passes through the low-precision format before
//! anything else reads it). The two-pass host implementation — produce
//! the full f32 product, then sweep it again with
//! [`Quantizer::apply_slice`] — pays one extra read+write of the whole
//! tensor per quantization site. The fused kernels in
//! [`crate::tensor::ops`] (`matmul_sl_q` & co.) instead run this
//! [`QuantEpilogue`] over each output tile while it is still cache-hot.
//!
//! Everything here is designed around one invariant, enforced by
//! `tests/fused_parity.rs`:
//!
//! > Splitting a tensor into tiles `(offset, slice)` and running the
//! > epilogue per tile produces **bit-identical outputs and identical
//! > [`QuantStats`] totals** to one whole-tensor sweep, for every
//! > rounding mode, at any tile size and any thread count.
//!
//! Two ingredients make that hold:
//!
//! * Statistics are `u64` *counters* (never rates), so per-tile
//!   [`QuantStats::merge`] is associative and order-insensitive.
//! * Stochastic rounding draws its uniform sample from [`ElemRng`], a
//!   counter-based stream keyed on the element's flat index in the
//!   *logical* tensor — not on iteration order — so any tiling or
//!   threading draws identical samples. (A sequential PRNG could never
//!   satisfy the invariant: its samples depend on visit order.)
//!
//! The integer-domain kernels ([`crate::tensor::int_gemm`]) run this
//! same epilogue over the exact f32 products they rescale out of i32
//! accumulators, which is what lets a *cached* weight pack
//! ([`crate::tensor::int_gemm::PackedCache`]) substitute for a fresh
//! one without touching the epilogue's inputs: packing is a pure
//! function of the operand values, so the epilogue sees bit-identical
//! products either way.

use super::float16;
use super::quantizer::{QuantStats, Quantizer};
use super::round::RoundMode;

/// SplitMix64 finalizer: the bit mixer behind [`ElemRng`].
#[inline]
fn mix(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Counter-based uniform stream for stochastic rounding.
///
/// `at(i)` depends only on `(seed, i)`, so the sample for element `i` of
/// a tensor is the same no matter which tile or thread visits it — the
/// property that lets the fused kernels stay bit-identical to the
/// two-pass sweep under `RoundMode::Stochastic`.
#[derive(Clone, Copy, Debug)]
pub struct ElemRng {
    seed: u64,
}

impl ElemRng {
    pub fn new(seed: u64) -> ElemRng {
        ElemRng { seed: mix(seed) }
    }

    /// Derive the stream for quantization site `site` of a multi-site
    /// consumer (the golden model numbers its sites in call order), so
    /// distinct sites never share samples.
    pub fn for_site(seed: u64, site: u64) -> ElemRng {
        ElemRng::new(seed ^ mix(site ^ 0xE1E3_57CC_0A57_F00D))
    }

    /// Uniform sample in `[0, 1)` for element index `i` (24-bit
    /// resolution, matching `Pcg32::uniform`).
    #[inline]
    pub fn at(&self, i: u64) -> f32 {
        let z = mix(self.seed ^ i.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        ((z >> 40) as u32) as f32 * (1.0 / (1u32 << 24) as f32)
    }
}

/// One quantization site, ready to run inside (or after) a GEMM: the
/// quantizer, the float16-simulation switch, the optional stochastic
/// sample stream, and the flat-index base of this call's output within
/// the logical tensor (non-zero when one logical tensor is produced by
/// several GEMM calls, e.g. the per-filter maxout contractions).
#[derive(Clone, Copy, Debug)]
pub struct QuantEpilogue {
    pub quant: Quantizer,
    /// Round-trip through IEEE binary16 instead of the fixed grid
    /// (`StepOptions::half`); only totals are counted.
    pub half: bool,
    /// Sample stream for `RoundMode::Stochastic`. `None` falls back to
    /// the midpoint sample 0.5, matching [`Quantizer::apply_slice`].
    pub rng: Option<ElemRng>,
    /// Flat-index offset of this call's output in the logical tensor.
    pub base: u64,
}

impl QuantEpilogue {
    /// Epilogue for a fixed-grid (or passthrough) quantizer.
    pub fn new(quant: Quantizer) -> QuantEpilogue {
        QuantEpilogue { quant, half: false, rng: None, base: 0 }
    }

    /// Epilogue for the float16 simulation (binary16 round-trip).
    pub fn half_sim() -> QuantEpilogue {
        QuantEpilogue { quant: Quantizer::float32(), half: true, rng: None, base: 0 }
    }

    /// Attach a stochastic-rounding sample stream.
    pub fn with_rng(mut self, rng: ElemRng) -> QuantEpilogue {
        self.rng = Some(rng);
        self
    }

    /// The same site with a different flat-index base (per-GEMM-call
    /// offsets into one logical tensor).
    pub fn with_base(mut self, base: u64) -> QuantEpilogue {
        self.base = base;
        self
    }

    /// Float32 passthrough: values are untouched (only totals counted),
    /// so fused kernels may skip per-element work entirely.
    pub fn is_noop(&self) -> bool {
        !self.half && self.quant.is_passthrough()
    }

    /// Quantize `xs` in place, where `xs` is the tile of the logical
    /// tensor starting at flat index `self.base + offset`. Returns the
    /// tile's overflow statistics.
    ///
    /// Bit-identical to [`Quantizer::apply_slice`] (fixed grids) and to
    /// a [`float16::half_roundtrip`] sweep (`half`) on the same data,
    /// for any split of the tensor into `(offset, tile)` pieces.
    pub fn run(&self, xs: &mut [f32], offset: u64) -> QuantStats {
        let mut st = QuantStats { n_total: xs.len() as u64, ..Default::default() };
        if self.half {
            for v in xs.iter_mut() {
                *v = float16::half_roundtrip(*v);
            }
            return st;
        }
        let q = self.quant;
        if q.is_passthrough() {
            return st;
        }
        let half = q.maxv * 0.5;
        match self.rng {
            Some(rng) if q.mode == RoundMode::Stochastic => {
                let start = self.base + offset;
                for (i, v) in xs.iter_mut().enumerate() {
                    let a = v.abs();
                    if a >= q.maxv {
                        st.n_over += 1;
                    }
                    if a >= half {
                        st.n_half += 1;
                    }
                    *v = q.apply_with(*v, rng.at(start + i as u64));
                }
            }
            _ => {
                for v in xs.iter_mut() {
                    let a = v.abs();
                    if a >= q.maxv {
                        st.n_over += 1;
                    }
                    if a >= half {
                        st.n_half += 1;
                    }
                    *v = q.apply_with(*v, 0.5);
                }
            }
        }
        st
    }

    /// Integer-aware variant of the epilogue, for the integer-domain GEMM
    /// path (`tensor::int_gemm` + the `*_qd` dispatch in `tensor::ops`):
    /// convert an i32 accumulator tile to f32 at the power-of-two `scale`,
    /// add the optional bias row (row width `n`), then run the standard
    /// [`QuantEpilogue::run`] over the tile.
    ///
    /// Under the int-GEMM eligibility bound (`|acc| ≤ 2^24` and `scale`
    /// in the exact-conversion exponent window — see
    /// `tensor::int_gemm`'s module docs) the conversion is exact, so this
    /// is bit-identical to running the f32 kernel + [`QuantEpilogue::run`]
    /// on the same tile — enforced by `tests/int_gemm_parity.rs`.
    #[allow(clippy::too_many_arguments)]
    pub fn run_int(
        &self,
        acc: &[i32],
        scale: f32,
        n: usize,
        bias: Option<&[f32]>,
        dst: &mut [f32],
        offset: u64,
    ) -> QuantStats {
        debug_assert_eq!(acc.len(), dst.len(), "run_int tile sizes");
        for (o, &v) in dst.iter_mut().zip(acc) {
            *o = v as f32 * scale;
        }
        self.run_biased(dst, n, bias, offset)
    }

    /// Bias-then-quantize over an f32 tile of row width `n`: add the
    /// bias row to every row in place, then [`QuantEpilogue::run`].
    /// The single implementation behind the f32 GEMM tile epilogues
    /// (`tensor::ops`), the direct conv reference path (`golden::conv`)
    /// and the split-accumulator integer runners — one place for the
    /// bias/quantize order so the paths cannot drift apart.
    pub fn run_biased(
        &self,
        xs: &mut [f32],
        n: usize,
        bias: Option<&[f32]>,
        offset: u64,
    ) -> QuantStats {
        if let Some(bs) = bias {
            for row in xs.chunks_mut(n) {
                for (o, &bv) in row.iter_mut().zip(bs) {
                    *o += bv;
                }
            }
        }
        self.run(xs, offset)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::Gen;

    #[test]
    fn elem_rng_is_deterministic_and_in_unit_interval() {
        let rng = ElemRng::new(42);
        for i in 0..10_000u64 {
            let u = rng.at(i);
            assert!((0.0..1.0).contains(&u), "i={i} u={u}");
            assert_eq!(u, ElemRng::new(42).at(i));
        }
    }

    #[test]
    fn elem_rng_streams_decorrelate_across_seeds_and_sites() {
        let a = ElemRng::new(1);
        let b = ElemRng::new(2);
        let same = (0..1000u64).filter(|&i| a.at(i) == b.at(i)).count();
        assert!(same < 5, "seeds collide: {same}");
        let s0 = ElemRng::for_site(7, 0);
        let s1 = ElemRng::for_site(7, 1);
        let same = (0..1000u64).filter(|&i| s0.at(i) == s1.at(i)).count();
        assert!(same < 5, "sites collide: {same}");
    }

    // NOTE: the epilogue == apply_slice bit-identity and the tiling
    // invariance are property-tested from the shared fixtures in
    // tests/quantizer_prop.rs; here only the fused-module-specific
    // surfaces (ElemRng, half_sim, noop) get unit coverage.

    #[test]
    fn half_sim_matches_roundtrip_sweep() {
        let mut g = Gen::new(0x5E11);
        let xs = g.vec_f32(64, 64, -100.0, 100.0);
        let mut a = xs.clone();
        let st = QuantEpilogue::half_sim().run(&mut a, 0);
        assert_eq!(st, QuantStats { n_over: 0, n_half: 0, n_total: 64 });
        for (got, &x) in a.iter().zip(&xs) {
            assert_eq!(got.to_bits(), float16::half_roundtrip(x).to_bits());
        }
    }

    #[test]
    fn noop_epilogue_counts_totals_only() {
        let epi = QuantEpilogue::new(Quantizer::float32());
        assert!(epi.is_noop());
        assert!(!QuantEpilogue::half_sim().is_noop());
        let mut xs = vec![1.5, -2.5e30, f32::MIN_POSITIVE];
        let orig = xs.clone();
        let st = epi.run(&mut xs, 0);
        assert_eq!(xs, orig);
        assert_eq!(st, QuantStats { n_over: 0, n_half: 0, n_total: 3 });
    }
}
