//! Software numeric-format substrate: the arithmetics the paper compares.
//!
//! The paper evaluates three arithmetics (sections 3–5): floating point
//! (float32 reference / float16), fixed point (one global scaling factor)
//! and dynamic fixed point (per-group scaling factors updated online from
//! overflow statistics). This module implements all three **in software on
//! the host**, bit-exactly mirroring the semantics baked into the L1
//! Pallas kernels, so that:
//!
//! * the rust *golden model* (`crate::golden`) can cross-validate the
//!   compiled HLO training step end to end,
//! * the coordinator can quantize host-side state (initial parameters,
//!   dataset preprocessing) identically to the device,
//! * property tests can probe formats far beyond what a training run
//!   exercises.
//!
//! Submodules:
//!
//! * [`format`]    — format descriptors: total/integer bit-widths, the
//!                   `(step, maxv)` runtime encoding shared with L2.
//! * [`round`]     — rounding primitives (half-away, half-even, stochastic,
//!                   truncate) on `f32`.
//! * [`fixed`]     — `QFixed`: a saturating software fixed point scalar.
//! * [`float16`]   — bit-level `f32 ↔ IEEE binary16` conversion (paper
//!                   Table 1) with round-to-nearest-even.
//! * [`quantizer`] — tensor-level quantization + overflow statistics,
//!                   the host twin of the Pallas kernel.
//! * [`fused`]     — the quantization epilogue the fused GEMM kernels run
//!                   per output tile, plus the counter-based stochastic
//!                   sample stream that keeps tiling bit-transparent.
//! * [`dynfixed`]  — per-group dynamic fixed point state + the paper's
//!                   section 5 update rule (also used by the coordinator's
//!                   scale controller).

pub mod dynfixed;
pub mod fixed;
pub mod float16;
pub mod format;
pub mod fused;
pub mod quantizer;
pub mod round;

pub use dynfixed::{GroupState, OverflowCounts, UpdateDecision};
pub use fixed::QFixed;
pub use format::FixedFormat;
pub use fused::{ElemRng, QuantEpilogue};
pub use quantizer::{QuantStats, Quantizer};
pub use round::RoundMode;
