//! Tensor-level quantization + overflow statistics: the host twin of the
//! L1 Pallas kernel (`python/compile/kernels/quantize.py`).
//!
//! Bit-for-bit contract with the device path (verified by the runtime
//! integration tests and the golden-model cross-check):
//!
//! ```text
//! y      = clip(round_half_away(x/step), -maxv/step, maxv/step - 1) * step
//! y      = x                                   when step == 0 (float32)
//! n_over = #{ |x| ≥ maxv }      n_half = #{ |x| ≥ maxv/2 }
//! ```

use super::format::FixedFormat;
use super::round::{half_away, RoundMode};

/// Overflow statistics for one quantization call (one group, one site).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct QuantStats {
    /// Elements that would saturate at the current scale (`|x| ≥ maxv`).
    pub n_over: u64,
    /// Elements that would saturate at *half* the scale (`|x| ≥ maxv/2`).
    pub n_half: u64,
    /// Total elements seen.
    pub n_total: u64,
}

impl QuantStats {
    /// Fold `other`'s counters into `self`.
    ///
    /// Merging carries raw `u64` **counters** — never rates — so it is
    /// associative and order-insensitive: any tiling of a tensor, merged
    /// in any order, yields the same totals and therefore the same
    /// [`Self::rate`]. The fused GEMM kernels rely on this to merge
    /// per-tile statistics without drifting from a single-pass sweep
    /// (regression-tested below; averaging per-tile *rates* would weight
    /// tiles equally regardless of size and break the invariant).
    pub fn merge(&mut self, other: QuantStats) {
        self.n_over += other.n_over;
        self.n_half += other.n_half;
        self.n_total += other.n_total;
    }

    /// Non-mutating [`Self::merge`] (fold helper for per-tile stats).
    #[must_use]
    pub fn merged(mut self, other: QuantStats) -> QuantStats {
        self.merge(other);
        self
    }

    /// Overflow rate at the current scale.
    pub fn rate(&self) -> f64 {
        if self.n_total == 0 {
            0.0
        } else {
            self.n_over as f64 / self.n_total as f64
        }
    }

    /// Overflow rate the group would see at half the scale.
    pub fn half_rate(&self) -> f64 {
        if self.n_total == 0 {
            0.0
        } else {
            self.n_half as f64 / self.n_total as f64
        }
    }
}

/// Tensor quantizer for a `(step, maxv)` pair, with pluggable rounding for
/// the ablation benches. The canonical mode (`HalfAway`) matches the
/// compiled artifacts exactly.
#[derive(Clone, Copy, Debug)]
pub struct Quantizer {
    pub step: f32,
    pub maxv: f32,
    pub mode: RoundMode,
}

impl Quantizer {
    /// Quantizer for a format descriptor with the canonical rounding.
    pub fn from_format(fmt: FixedFormat) -> Self {
        Quantizer { step: fmt.step(), maxv: fmt.maxv(), mode: RoundMode::HalfAway }
    }

    /// Float32 passthrough quantizer.
    pub fn float32() -> Self {
        Quantizer { step: 0.0, maxv: 0.0, mode: RoundMode::HalfAway }
    }

    pub fn is_passthrough(&self) -> bool {
        self.step <= 0.0
    }

    /// Quantize one value (canonical kernel formula).
    #[inline]
    pub fn apply(&self, x: f32) -> f32 {
        if self.is_passthrough() {
            return x;
        }
        let lim_lo = -self.maxv / self.step;
        let lim_hi = self.maxv / self.step - 1.0;
        half_away(x / self.step).clamp(lim_lo, lim_hi) * self.step
    }

    /// Quantize one value with this quantizer's rounding mode (`u` feeds
    /// stochastic rounding; ignored by deterministic modes).
    #[inline]
    pub fn apply_with(&self, x: f32, u: f32) -> f32 {
        if self.is_passthrough() {
            return x;
        }
        let lim_lo = -self.maxv / self.step;
        let lim_hi = self.maxv / self.step - 1.0;
        self.mode.round(x / self.step, u).clamp(lim_lo, lim_hi) * self.step
    }

    /// Quantize a slice in place, returning overflow statistics. Rounds
    /// with the configured [`RoundMode`] (stochastic uses the midpoint
    /// sample 0.5 here — callers that want true stochastic rounding drive
    /// [`Self::apply_with`] with their own PRNG, as the golden model does).
    pub fn apply_slice(&self, xs: &mut [f32]) -> QuantStats {
        let mut stats =
            QuantStats { n_over: 0, n_half: 0, n_total: xs.len() as u64 };
        if self.is_passthrough() {
            return stats;
        }
        let half = self.maxv * 0.5;
        for x in xs.iter_mut() {
            let a = x.abs();
            if a >= self.maxv {
                stats.n_over += 1;
            }
            if a >= half {
                stats.n_half += 1;
            }
            *x = self.apply_with(*x, 0.5);
        }
        stats
    }

    /// Statistics only (no mutation) — what the value *would* do.
    pub fn stats_only(&self, xs: &[f32]) -> QuantStats {
        let mut stats =
            QuantStats { n_over: 0, n_half: 0, n_total: xs.len() as u64 };
        if self.is_passthrough() {
            return stats;
        }
        let half = self.maxv * 0.5;
        for &x in xs {
            let a = x.abs();
            if a >= self.maxv {
                stats.n_over += 1;
            }
            if a >= half {
                stats.n_half += 1;
            }
        }
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{forall, Gen};

    fn q(total_bits: i32, int_bits: i32) -> Quantizer {
        Quantizer::from_format(FixedFormat::new(total_bits, int_bits))
    }

    #[test]
    fn passthrough_is_identity_with_zero_counts() {
        let qz = Quantizer::float32();
        let mut xs = vec![1.5, -2.7, 1e20, f32::MIN_POSITIVE];
        let orig = xs.clone();
        let st = qz.apply_slice(&mut xs);
        assert_eq!(xs, orig);
        assert_eq!(st, QuantStats { n_over: 0, n_half: 0, n_total: 4 });
    }

    #[test]
    fn output_always_on_grid_and_in_range() {
        forall("grid membership", |g: &mut Gen| {
            let quant = q(g.i32_range(2, 24), g.i32_range(-4, 8));
            let x = g.f32_range(-1e4, 1e4);
            let y = quant.apply(x);
            let k = y / quant.step;
            assert!((k - k.round()).abs() < 1e-3, "off grid: x={x} y={y}");
            assert!(y >= -quant.maxv && y <= quant.maxv - quant.step * 0.999);
        });
    }

    #[test]
    fn idempotent() {
        forall("idempotence", |g: &mut Gen| {
            let quant = q(g.i32_range(2, 24), g.i32_range(-4, 8));
            let x = g.f32_range(-100.0, 100.0);
            let y = quant.apply(x);
            assert_eq!(quant.apply(y), y);
        });
    }

    #[test]
    fn monotone() {
        forall("monotonicity", |g: &mut Gen| {
            let quant = q(g.i32_range(3, 20), g.i32_range(-2, 6));
            let a = g.f32_range(-50.0, 50.0);
            let b = g.f32_range(-50.0, 50.0);
            let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
            assert!(quant.apply(lo) <= quant.apply(hi));
        });
    }

    #[test]
    fn error_bounded_by_half_step_inside_range() {
        forall("error bound", |g: &mut Gen| {
            let quant = q(g.i32_range(4, 24), g.i32_range(0, 6));
            let x = g.f32_range(-quant.maxv * 0.9, quant.maxv * 0.9);
            let y = quant.apply(x);
            assert!((y - x).abs() <= quant.step * 0.5 + 1e-6);
        });
    }

    #[test]
    fn counters_match_definition() {
        let quant = q(8, 2); // maxv 4
        let xs = [0.0f32, 1.0, 2.0, 3.9, 4.0, -4.0, -5.0, 100.0];
        let st = quant.stats_only(&xs);
        assert_eq!(st.n_over, 4); // |x| ≥ 4
        assert_eq!(st.n_half, 6); // |x| ≥ 2
        assert_eq!(st.n_total, 8);
        assert!((st.rate() - 0.5).abs() < 1e-12);
        assert!((st.half_rate() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn matches_python_oracle_vectors() {
        // Golden vectors produced by compile/kernels/ref.py (quantize_ref).
        let quant = q(10, 3); // step = 2^-6 = 0.015625, maxv = 8
        let cases = [
            (0.0f32, 0.0f32),
            (1.0, 1.0),
            (0.007812499, 0.0),      // just below the step/2 tie → 0
            (0.0078125, 0.015625),   // exactly step/2: half-away rounds up
            (0.01, 0.015625),
            (-3.3333, -3.328125),
            (7.9999, 7.984375), // lim_hi = maxv - step
            (8.0, 7.984375),
            (-8.0, -8.0),
            (-9.0, -8.0),
        ];
        for (x, want) in cases {
            let got = quant.apply(x);
            assert_eq!(got, want, "x={x}");
        }
    }

    #[test]
    fn merge_accumulates() {
        let mut a = QuantStats { n_over: 1, n_half: 2, n_total: 10 };
        a.merge(QuantStats { n_over: 3, n_half: 4, n_total: 20 });
        assert_eq!(a, QuantStats { n_over: 4, n_half: 6, n_total: 30 });
        assert_eq!(a, QuantStats { n_over: 1, n_half: 2, n_total: 10 }
            .merged(QuantStats { n_over: 3, n_half: 4, n_total: 20 }));
    }

    #[test]
    fn merge_is_associative_and_order_insensitive() {
        // The fused kernels merge per-tile stats in tile order, but the
        // contract must not depend on it: counters (and the rates derived
        // from them) are identical for any association or permutation.
        forall("merge associativity", |g: &mut Gen| {
            let tiles: Vec<QuantStats> = (0..g.usize_range(1, 8))
                .map(|_| {
                    let n_total = g.u64() % 1000;
                    let n_half = if n_total == 0 { 0 } else { g.u64() % (n_total + 1) };
                    let n_over = if n_half == 0 { 0 } else { g.u64() % (n_half + 1) };
                    QuantStats { n_over, n_half, n_total }
                })
                .collect();
            // left fold
            let mut left = QuantStats::default();
            for &t in &tiles {
                left.merge(t);
            }
            // right-associated fold
            let mut right = QuantStats::default();
            for &t in tiles.iter().rev() {
                right = t.merged(right);
            }
            // a rotated order
            let mut rotated = QuantStats::default();
            let pivot = g.usize_range(0, tiles.len() - 1);
            for &t in tiles[pivot..].iter().chain(&tiles[..pivot]) {
                rotated.merge(t);
            }
            assert_eq!(left, right);
            assert_eq!(left, rotated);
            assert_eq!(left.rate().to_bits(), right.rate().to_bits());
            assert_eq!(left.half_rate().to_bits(), rotated.half_rate().to_bits());
        });
    }

    #[test]
    fn rates_come_from_merged_counters_not_averaged_tile_rates() {
        // Regression guard for the drift the counter contract prevents:
        // two tiles of different sizes — the merged rate weights by tile
        // size; a mean of per-tile rates would not.
        let a = QuantStats { n_over: 1, n_half: 1, n_total: 2 }; // rate 0.5
        let b = QuantStats { n_over: 0, n_half: 0, n_total: 8 }; // rate 0.0
        let merged = a.merged(b);
        assert_eq!(merged.rate(), 0.1);
        let mean_of_rates = (a.rate() + b.rate()) / 2.0; // 0.25 — wrong
        assert!((merged.rate() - mean_of_rates).abs() > 0.1);
    }
}
