//! Rounding primitives for fixed point quantization.
//!
//! The paper's simulation rounds every stored value to the nearest grid
//! point; the stack's canonical mode is **half-away-from-zero** (classic
//! DSP fixed point rounding, and what the L1 Pallas kernel implements:
//! `sign(x)·floor(|x| + 0.5)`). The other modes exist for the ablation
//! bench (`benches/bench_ablation.rs`): half-even removes the systematic
//! bias of half-away on exactly-representable ties, truncation is the
//! cheapest hardware option, and stochastic rounding is the
//! forward-looking comparison point (Gupta et al. 2015 showed it matters
//! at even lower widths).

/// How to map a real value to an integer grid index.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoundMode {
    /// Round to nearest; ties away from zero. The stack default — matches
    /// the Pallas kernel and the HLO artifacts bit for bit.
    HalfAway,
    /// Round to nearest; ties to the even integer (IEEE default).
    HalfEven,
    /// Truncate toward zero (drop fraction bits) — cheapest in hardware.
    Truncate,
    /// Stochastic: round up with probability equal to the fractional part.
    /// Unbiased in expectation; needs a caller-supplied uniform sample.
    Stochastic,
}

impl RoundMode {
    /// Round `x` (already divided by the quantization step) to an integer.
    /// `u` is a uniform sample in [0, 1), used only by `Stochastic`.
    #[inline]
    pub fn round(self, x: f32, u: f32) -> f32 {
        match self {
            RoundMode::HalfAway => half_away(x),
            RoundMode::HalfEven => half_even(x),
            RoundMode::Truncate => x.trunc(),
            RoundMode::Stochastic => stochastic(x, u),
        }
    }
}

/// Round to nearest, ties away from zero: `sign(x) * floor(|x| + 0.5)`.
#[inline]
pub fn half_away(x: f32) -> f32 {
    x.signum() * (x.abs() + 0.5).floor()
}

/// Round to nearest, ties to even (IEEE round-to-nearest-even).
#[inline]
pub fn half_even(x: f32) -> f32 {
    // f32::round_ties_even is stable since 1.77.
    x.round_ties_even()
}

/// Stochastic rounding: floor(x) + Bernoulli(frac(x)).
#[inline]
pub fn stochastic(x: f32, u: f32) -> f32 {
    let fl = x.floor();
    let frac = x - fl;
    if u < frac {
        fl + 1.0
    } else {
        fl
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{forall, Gen};

    #[test]
    fn half_away_matches_kernel_semantics() {
        // The L1 kernel computes sign(x)*floor(|x|+0.5); spot-check ties.
        for (x, want) in [
            (0.5, 1.0),
            (-0.5, -1.0),
            (1.5, 2.0),
            (-1.5, -2.0),
            (2.5, 3.0),
            (-2.5, -3.0),
            (0.49, 0.0),
            (-0.49, -0.0),
        ] {
            assert_eq!(half_away(x), want, "x={x}");
        }
    }

    #[test]
    fn half_even_ties() {
        for (x, want) in [(0.5, 0.0), (1.5, 2.0), (2.5, 2.0), (-2.5, -2.0)] {
            assert_eq!(half_even(x), want, "x={x}");
        }
    }

    #[test]
    fn truncate_toward_zero() {
        assert_eq!(RoundMode::Truncate.round(1.9, 0.0), 1.0);
        assert_eq!(RoundMode::Truncate.round(-1.9, 0.0), -1.0);
    }

    #[test]
    fn stochastic_is_floor_or_ceil() {
        forall("stochastic bounds", |g: &mut Gen| {
            let x = g.f32_range(-100.0, 100.0);
            let u = g.f32_range(0.0, 1.0);
            let r = stochastic(x, u);
            assert!(r == x.floor() || r == x.floor() + 1.0, "x={x} u={u} r={r}");
        });
    }

    #[test]
    fn stochastic_unbiased_in_expectation() {
        // E[round(x)] == x for the fractional part, up to sampling error.
        let x = 3.25f32;
        let n = 20_000;
        let mut acc = 0f64;
        let mut g = Gen::new(42);
        for _ in 0..n {
            acc += stochastic(x, g.f32_range(0.0, 1.0)) as f64;
        }
        let mean = acc / n as f64;
        assert!((mean - 3.25).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn all_modes_exact_on_integers() {
        forall("integers fixed", |g: &mut Gen| {
            let k = g.i32_range(-1000, 1000) as f32;
            for mode in [
                RoundMode::HalfAway,
                RoundMode::HalfEven,
                RoundMode::Truncate,
                RoundMode::Stochastic,
            ] {
                assert_eq!(mode.round(k, 0.3), k, "mode={mode:?}");
            }
        });
    }

    #[test]
    fn nearest_modes_within_half() {
        forall("nearest error bound", |g: &mut Gen| {
            let x = g.f32_range(-1e4, 1e4);
            assert!((half_away(x) - x).abs() <= 0.5 + 1e-3);
            assert!((half_even(x) - x).abs() <= 0.5 + 1e-3);
        });
    }
}
