//! Dynamic fixed point group state + the paper's update rule (section 5).
//!
//! In dynamic fixed point, a few grouped variables (one layer's weights,
//! or its weighted sums, or the gradients of its outputs, ...) share one
//! scaling factor that is updated *online* from overflow statistics:
//!
//! > "We update the scaling factors at a given frequency: if the overflow
//! > rate associated with a scaling factor is superior to a given maximum
//! > overflow rate, we multiply this scaling factor by two. If the
//! > overflow rate associated with the half of a scaling factor is
//! > inferior to the maximum overflow rate, we divide this scaling factor
//! > by two."
//!
//! The compiled train step reports, per group, exactly the two counters
//! this rule needs: `n_over = #{|x| ≥ maxv}` (rate at the current scale)
//! and `n_half = #{|x| ≥ maxv/2}` (the rate the group *would* see at half
//! the scale). [`GroupState`] accumulates them between update ticks; the
//! coordinator calls [`GroupState::maybe_update`] every
//! `update_every_examples` examples (paper: 10 000; max rate 0.01%).

use super::format::FixedFormat;
use super::quantizer::QuantStats;

/// Per-call overflow counters (alias of the quantizer's statistics type:
/// they are the same three numbers).
pub type OverflowCounts = QuantStats;

/// What the update rule decided at a tick.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UpdateDecision {
    /// Overflowing too often → double the scaling factor (one more
    /// integer bit, one less fraction bit).
    ScaleUp,
    /// Even half the scale would be overflow-safe → halve the scaling
    /// factor (gain one fraction bit of precision).
    ScaleDown,
    /// Leave the scale as is.
    Hold,
}

/// One scaling-factor group's dynamic state.
#[derive(Clone, Debug)]
pub struct GroupState {
    /// Current format. `total_bits` is fixed by the experiment config;
    /// `int_bits` is what the controller moves.
    pub fmt: FixedFormat,
    /// Counters accumulated since the last update tick.
    acc: OverflowCounts,
    /// Clamp for `int_bits` (avoids f32-degenerate scales on pathological
    /// inputs; wide enough to never bind in the paper's regime).
    pub int_bits_min: i32,
    pub int_bits_max: i32,
}

impl GroupState {
    pub fn new(fmt: FixedFormat) -> Self {
        GroupState { fmt, acc: OverflowCounts::default(), int_bits_min: -24, int_bits_max: 24 }
    }

    /// Feed one train step's counters for this group.
    pub fn observe(&mut self, counts: OverflowCounts) {
        self.acc.merge(counts);
    }

    /// Counters accumulated since the last tick (for metrics/logging).
    pub fn pending(&self) -> OverflowCounts {
        self.acc
    }

    /// Apply the paper's rule and reset the accumulator. `max_rate` is the
    /// maximum overflow rate (paper default 1e-4, i.e. 0.01%).
    pub fn maybe_update(&mut self, max_rate: f64) -> UpdateDecision {
        let decision = if self.acc.n_total == 0 {
            UpdateDecision::Hold
        } else if self.acc.rate() > max_rate && self.fmt.int_bits < self.int_bits_max {
            UpdateDecision::ScaleUp
        } else if self.acc.half_rate() < max_rate && self.fmt.int_bits > self.int_bits_min {
            UpdateDecision::ScaleDown
        } else {
            UpdateDecision::Hold
        };
        match decision {
            UpdateDecision::ScaleUp => self.fmt = self.fmt.scale_up(),
            UpdateDecision::ScaleDown => self.fmt = self.fmt.scale_down(),
            UpdateDecision::Hold => {}
        }
        self.acc = OverflowCounts::default();
        decision
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{forall, Gen};

    fn state(int_bits: i32) -> GroupState {
        GroupState::new(FixedFormat::new(12, int_bits))
    }

    fn counts(over: u64, half: u64, total: u64) -> OverflowCounts {
        OverflowCounts { n_over: over, n_half: half, n_total: total }
    }

    #[test]
    fn overflowing_group_scales_up() {
        let mut s = state(2);
        s.observe(counts(100, 200, 10_000)); // rate 1% > 0.01%
        assert_eq!(s.maybe_update(1e-4), UpdateDecision::ScaleUp);
        assert_eq!(s.fmt.int_bits, 3);
    }

    #[test]
    fn quiet_group_scales_down() {
        let mut s = state(2);
        s.observe(counts(0, 0, 10_000)); // even half scale never overflows
        assert_eq!(s.maybe_update(1e-4), UpdateDecision::ScaleDown);
        assert_eq!(s.fmt.int_bits, 1);
    }

    #[test]
    fn boundary_group_holds() {
        let mut s = state(2);
        // current scale fine (rate ≤ max), half scale would overflow too
        // often (half_rate ≥ max) → exactly the paper's steady state.
        s.observe(counts(0, 50, 10_000)); // half_rate 0.5% ≥ 0.01%
        assert_eq!(s.maybe_update(1e-4), UpdateDecision::Hold);
        assert_eq!(s.fmt.int_bits, 2);
    }

    #[test]
    fn accumulator_resets_after_tick() {
        let mut s = state(0);
        s.observe(counts(1000, 1000, 1000));
        s.maybe_update(1e-4);
        assert_eq!(s.pending(), OverflowCounts::default());
        // no new observations → Hold
        assert_eq!(s.maybe_update(1e-4), UpdateDecision::Hold);
    }

    #[test]
    fn respects_clamps() {
        let mut s = state(24);
        s.observe(counts(1000, 1000, 1000));
        assert_eq!(s.maybe_update(1e-4), UpdateDecision::Hold); // at max

        let mut s = state(-24);
        s.observe(counts(0, 0, 1000));
        assert_eq!(s.maybe_update(1e-4), UpdateDecision::Hold); // at min
    }

    #[test]
    fn up_has_priority_over_down() {
        // Pathological: overflowing AND quiet-at-half cannot both be true
        // (n_half ≥ n_over by definition), but if rates straddle max_rate
        // the rule must prefer range (ScaleUp).
        let mut s = state(0);
        s.observe(counts(20, 20, 10_000)); // rate 0.2% > 0.01%
        assert_eq!(s.maybe_update(1e-4), UpdateDecision::ScaleUp);
    }

    #[test]
    fn converges_to_stable_scale_on_stationary_data() {
        // Simulated stationary distribution: |x| ~ N(0, 1). The controller
        // must settle at the int_bits where rate ≤ max < half-scale rate.
        forall("controller convergence", |g: &mut Gen| {
            let mut s = state(g.i32_range(-6, 10));
            let max_rate = 1e-3;
            let mut last = s.fmt.int_bits;
            let mut stable = 0;
            for _ in 0..60 {
                // Draw a batch; count overflow at the current scale.
                let maxv = s.fmt.maxv() as f64;
                let (mut over, mut half) = (0u64, 0u64);
                let n = 2000u64;
                for _ in 0..n {
                    let x = g.f32_normal(0.0, 1.0).abs() as f64;
                    if x >= maxv {
                        over += 1;
                    }
                    if x >= maxv / 2.0 {
                        half += 1;
                    }
                }
                s.observe(counts(over, half, n));
                s.maybe_update(max_rate);
                if s.fmt.int_bits == last {
                    stable += 1;
                } else {
                    stable = 0;
                    last = s.fmt.int_bits;
                }
            }
            // N(0,1): P(|x| ≥ 4) ≈ 6e-5 < 1e-3 < P(|x| ≥ 2) ≈ 0.046
            // → stable point is int_bits = 2 (maxv 4); allow ±1 for
            // sampling noise at the decision boundary.
            assert!(
                (1..=3).contains(&s.fmt.int_bits),
                "settled at {}",
                s.fmt.int_bits
            );
            assert!(stable >= 5, "never stabilized (last window {stable})");
        });
    }
}
