//! `QFixed`: a saturating software fixed point scalar (paper section 4).
//!
//! This is the "what the dedicated hardware would actually hold" view: a
//! signed integer mantissa plus a [`FixedFormat`]. The tensor-level
//! [`crate::arith::Quantizer`] operates on f32 for speed; `QFixed` is the
//! bit-true model used by property tests to prove the f32 path and the
//! integer path agree, and by the format-explorer example to show real
//! mantissa bit patterns.
//!
//! Arithmetic follows classic DSP fixed point rules:
//! * add/sub: same format, saturating on overflow;
//! * mul: full-precision intermediate (i64), then rounded back to the
//!   format with the configured [`RoundMode`] and saturated —
//!   equivalently, a wide accumulator feeding a narrow store, the paper's
//!   section 7 hardware hypothesis.

use super::format::FixedFormat;
use super::round::RoundMode;

/// A value on the fixed point grid: `value = mantissa * format.step()`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QFixed {
    /// Signed mantissa, confined to `[-2^(B-1), 2^(B-1) - 1]`.
    pub mantissa: i64,
    pub format: FixedFormat,
}

impl QFixed {
    /// Lowest representable mantissa for the format.
    fn man_min(fmt: FixedFormat) -> i64 {
        -(1i64 << (fmt.total_bits - 1))
    }

    /// Highest representable mantissa for the format.
    fn man_max(fmt: FixedFormat) -> i64 {
        (1i64 << (fmt.total_bits - 1)) - 1
    }

    fn saturate(m: i64, fmt: FixedFormat) -> i64 {
        m.clamp(Self::man_min(fmt), Self::man_max(fmt))
    }

    /// Quantize an f32 onto the grid (round + saturate).
    pub fn from_f32(x: f32, fmt: FixedFormat, mode: RoundMode, u: f32) -> Self {
        assert!(!fmt.is_float32(), "QFixed requires a concrete format");
        let scaled = x / fmt.step();
        let m = mode.round(scaled, u) as i64;
        QFixed { mantissa: Self::saturate(m, fmt), format: fmt }
    }

    /// The real value this mantissa represents.
    pub fn to_f32(self) -> f32 {
        self.mantissa as f32 * self.format.step()
    }

    /// Saturating addition (same format required).
    pub fn add(self, rhs: QFixed) -> QFixed {
        assert_eq!(self.format, rhs.format, "format mismatch");
        QFixed {
            mantissa: Self::saturate(self.mantissa + rhs.mantissa, self.format),
            format: self.format,
        }
    }

    /// Saturating subtraction (same format required).
    pub fn sub(self, rhs: QFixed) -> QFixed {
        assert_eq!(self.format, rhs.format, "format mismatch");
        QFixed {
            mantissa: Self::saturate(self.mantissa - rhs.mantissa, self.format),
            format: self.format,
        }
    }

    /// Multiplication with a wide (i64) intermediate, rounded back to the
    /// format. `m1*m2*step²/step = m1*m2*step`, so the product mantissa is
    /// `round(m1*m2*step)` — one shift when step is a power of two.
    pub fn mul(self, rhs: QFixed, mode: RoundMode, u: f32) -> QFixed {
        assert_eq!(self.format, rhs.format, "format mismatch");
        let fmt = self.format;
        let wide = self.mantissa as i128 * rhs.mantissa as i128; // exact
        // wide * step is the product in units of `step`; do it in f64 to
        // keep 53 bits of the intermediate (enough for B ≤ 26 mantissas).
        let scaled = wide as f64 * fmt.step() as f64;
        let m = mode.round(scaled as f32, u) as i64;
        QFixed { mantissa: Self::saturate(m, fmt), format: fmt }
    }

    /// True iff `x` would saturate at this format (feeds overflow counters).
    pub fn overflows(x: f32, fmt: FixedFormat) -> bool {
        x.abs() >= fmt.maxv()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{forall, Gen};

    const FMT: FixedFormat = FixedFormat::new(12, 3);

    #[test]
    fn roundtrip_on_grid_values() {
        forall("grid roundtrip", |g: &mut Gen| {
            let m = g.i32_range(-2048, 2047) as i64;
            let q = QFixed { mantissa: m, format: FMT };
            let back = QFixed::from_f32(q.to_f32(), FMT, RoundMode::HalfAway, 0.0);
            assert_eq!(back.mantissa, m);
        });
    }

    #[test]
    fn from_f32_agrees_with_kernel_formula() {
        // The Pallas kernel: clip(round_half_away(x/step), lo, hi) * step.
        forall("f32 vs integer path", |g: &mut Gen| {
            let x = g.f32_range(-20.0, 20.0);
            let q = QFixed::from_f32(x, FMT, RoundMode::HalfAway, 0.0);
            let step = FMT.step();
            let lim_lo = -FMT.maxv() / step;
            let lim_hi = FMT.maxv() / step - 1.0;
            let expect =
                (((x / step).abs() + 0.5).floor().copysign(x)).clamp(lim_lo, lim_hi) * step;
            assert!(
                (q.to_f32() - expect).abs() < 1e-6,
                "x={x} got={} want={expect}",
                q.to_f32()
            );
        });
    }

    #[test]
    fn saturation_at_extremes() {
        let hi = QFixed::from_f32(1e9, FMT, RoundMode::HalfAway, 0.0);
        assert_eq!(hi.to_f32(), FMT.maxv() - FMT.step());
        let lo = QFixed::from_f32(-1e9, FMT, RoundMode::HalfAway, 0.0);
        assert_eq!(lo.to_f32(), -FMT.maxv());
    }

    #[test]
    fn add_saturates_not_wraps() {
        let a = QFixed::from_f32(7.9, FMT, RoundMode::HalfAway, 0.0);
        let s = a.add(a);
        assert_eq!(s.mantissa, 2047); // man_max, not wrapped negative
    }

    #[test]
    fn mul_matches_f32_within_one_ulp_of_grid() {
        forall("mul accuracy", |g: &mut Gen| {
            let a = QFixed::from_f32(g.f32_range(-2.0, 2.0), FMT, RoundMode::HalfAway, 0.0);
            let b = QFixed::from_f32(g.f32_range(-2.0, 2.0), FMT, RoundMode::HalfAway, 0.0);
            let p = a.mul(b, RoundMode::HalfAway, 0.0);
            let exact = a.to_f32() * b.to_f32();
            // wide accumulator then one rounding: within half a step unless
            // saturated.
            if exact.abs() < FMT.maxv() - FMT.step() {
                assert!(
                    (p.to_f32() - exact).abs() <= FMT.step() * 0.5 + 1e-6,
                    "a={} b={} p={} exact={exact}",
                    a.to_f32(),
                    b.to_f32(),
                    p.to_f32()
                );
            }
        });
    }

    #[test]
    fn overflow_predicate_matches_maxv() {
        assert!(QFixed::overflows(8.0, FMT));
        assert!(QFixed::overflows(-8.0, FMT));
        assert!(!QFixed::overflows(7.99, FMT));
    }
}
