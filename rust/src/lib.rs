//! # lpdnn — Low Precision Arithmetic for Deep Learning
//!
//! A production-grade reproduction of *Courbariaux, David & Bengio (2014),
//! "Low Precision Arithmetic for Deep Learning"* (arXiv:1412.7024; first
//! posted as *"Training deep neural networks with low precision
//! multiplications"*) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`): fused
//!   quantize-with-overflow-stats and fused maxout-dense forward.
//! * **L2** — JAX maxout networks with explicit manual backprop and
//!   quantization hooks at every signal the paper names
//!   (`python/compile/model.py`), AOT-lowered once to HLO text.
//! * **L3** — this crate: the training coordinator, the dynamic fixed
//!   point scale controller (the paper's section 5 mechanism), every
//!   substrate (datasets, preprocessing, config, metrics), and the
//!   pluggable execution [`runtime::Backend`]s — the pure-Rust
//!   [`runtime::NativeBackend`] (default, self-contained) and the PJRT
//!   runtime that executes the compiled artifacts (behind the `pjrt`
//!   cargo feature). Python never runs on the training path.
//!
//! See `DESIGN.md` for the full system inventory and experiment index, and
//! `EXPERIMENTS.md` for reproduction results of every paper table/figure.

pub mod arith;
pub mod bench_support;
pub mod checkpoint;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod golden;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod testing;

/// Crate-wide result alias.
pub type Result<T> = error::Result<T>;
