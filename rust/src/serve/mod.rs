//! `lpdnn serve`: a batched, multi-threaded quantized-inference server
//! (DESIGN.md §Serving).
//!
//! The deployment case every related paper motivates — run the trained
//! low-precision network forward-only, at serving concurrency — wired
//! as three thread roles around two bounded queues:
//!
//! ```text
//! producers (N) ──► request queue ──► batcher (1) ──► batch queue ──► workers (W)
//!      ▲                                (max-batch /                      │
//!      └───────────── response slots ◄── max-wait-µs) ◄──────────────────┘
//! ```
//!
//! * **Producers** submit single examples and block on a per-request
//!   response slot — the built-in closed-loop load generator
//!   (`--requests`, `--concurrency`) measures end-to-end latency here.
//! * The **batcher** drains the request queue under a max-batch-size /
//!   max-wait policy: a batch ships as soon as it fills, or when the
//!   oldest queued request has waited `max_wait`, whichever is first.
//! * **Workers** each own a private [`Network`] (layer scratch is not
//!   shareable across threads) over shared `Arc` parameters, run the
//!   fused quantized forward pass ([`Network::eval_logits_opt`], with
//!   [`StepOptions::int_domain`] honored so the integer-domain kernels
//!   serve traffic), and fulfill each request's slot. Because the
//!   `Network` lives for the worker's whole lifetime, per-layer state
//!   amortizes across every batch it answers: the conv im2col scratch
//!   buffers allocate once, and with the integer domain enabled each
//!   worker pre-packs all weight operands **once at startup**
//!   ([`Network::prepack_int_operands`]) instead of per GEMM — weights
//!   are static at inference time. The report's `weight_packs` row
//!   counts pack-cache builds across all workers as proof.
//!
//! **Determinism under concurrency:** batch composition is timing
//! dependent — two runs will batch requests differently — but responses
//! are not. The forward pass is row-independent (per-output-element
//! accumulation order is fixed regardless of how many rows share the
//! GEMM; maxout/pool/softmax are per-example), eval rounds half-away
//! (no stochastic stream), and the integer-domain dispatch is
//! bit-identical to the simulated path whenever it engages. So every
//! response is bit-identical to a single-example forward pass of the
//! same checkpoint, whatever the batching, worker count, or
//! `LPDNN_INT_GEMM` setting — proven per-request in `tests/serve.rs`.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::arith::RoundMode;
use crate::bench_support::Table;
use crate::checkpoint::Restored;
use crate::data::Split;
use crate::golden::{fused_default, int_gemm_default, Network, Params, StepOptions};
use crate::tensor::{ops, Pcg32, Tensor};
use crate::{bail, ensure};

/// Serving/load-generator knobs (`lpdnn serve` flags).
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Total requests the load generator issues.
    pub requests: usize,
    /// Producer threads (closed loop: each has one request in flight).
    pub concurrency: usize,
    /// Inference worker threads (each with a private network).
    pub workers: usize,
    /// Largest batch the batcher assembles.
    pub max_batch: usize,
    /// Longest the batcher holds a non-full batch open.
    pub max_wait: Duration,
    /// Request-queue capacity (back-pressure bound).
    pub queue_cap: usize,
    /// Kernel selection for the forward pass (mode and float16
    /// simulation come from the checkpoint's arithmetic).
    pub fused: bool,
    pub int_domain: bool,
    /// Open-loop arrival rate in requests/sec ([`serve_open_loop`]);
    /// `0.0` means closed-loop only. Closed-loop producers re-submit on
    /// response, so their latency tail can never show a server falling
    /// behind — Poisson arrivals keep submitting on schedule and expose
    /// honest queueing delay in the percentiles.
    pub open_rate: f64,
    /// Seed for the Poisson arrival schedule (deterministic offsets).
    pub open_seed: u64,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            requests: 256,
            concurrency: 4,
            workers: 2,
            max_batch: 32,
            max_wait: Duration::from_micros(2000),
            queue_cap: 64,
            fused: fused_default(),
            int_domain: int_gemm_default(),
            open_rate: 0.0,
            open_seed: 1,
        }
    }
}

/// One fulfilled request.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: usize,
    /// The network's logits row for this example (`n_classes` values).
    pub logits: Vec<f32>,
    pub pred: usize,
    /// Submit → response, as the producer experienced it.
    pub latency: Duration,
}

/// A per-request rendezvous: the producer blocks on it, a worker
/// fulfills it.
#[derive(Default)]
struct Slot {
    state: Mutex<Option<Response>>,
    ready: Condvar,
}

impl Slot {
    fn fulfill(&self, r: Response) {
        *self.state.lock().unwrap() = Some(r);
        self.ready.notify_one();
    }

    fn wait(&self) -> Response {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(r) = st.take() {
                return r;
            }
            st = self.ready.wait(st).unwrap();
        }
    }
}

/// One in-flight request.
struct Request {
    id: usize,
    example: Vec<f32>,
    submitted: Instant,
    slot: Arc<Slot>,
}

struct QueueState<T> {
    /// Entries carry their enqueue time: `pop_batch`'s max-wait bound
    /// is on how long the *oldest* entry has been queued, so the stamp
    /// must be taken when the item enters, not when the batcher gets
    /// around to it.
    items: VecDeque<(Instant, T)>,
    closed: bool,
}

/// A bounded MPMC queue (mutex + condvars — no external crates) with a
/// batch-draining pop for the batcher side.
struct BoundedQueue<T> {
    state: Mutex<QueueState<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    cap: usize,
}

impl<T> BoundedQueue<T> {
    fn new(cap: usize) -> BoundedQueue<T> {
        BoundedQueue {
            state: Mutex::new(QueueState { items: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            cap: cap.max(1),
        }
    }

    /// Block until there is room; `false` if the queue closed instead.
    fn push(&self, item: T) -> bool {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.closed {
                return false;
            }
            if st.items.len() < self.cap {
                st.items.push_back((Instant::now(), item));
                self.not_empty.notify_one();
                return true;
            }
            st = self.not_full.wait(st).unwrap();
        }
    }

    /// Block for one item; `None` once the queue is closed *and* drained.
    fn pop(&self) -> Option<T> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some((_, item)) = st.items.pop_front() {
                self.not_full.notify_one();
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = self.not_empty.wait(st).unwrap();
        }
    }

    /// The batching policy: block for the first item, then keep the
    /// batch open until it has `max_n` items or the **oldest** item has
    /// been queued for `max_wait` — the deadline keys off the first
    /// item's *enqueue* stamp, so time a request already spent waiting
    /// for the batcher counts against its wait budget. Empty result ⇔
    /// closed and drained.
    fn pop_batch(&self, max_n: usize, max_wait: Duration) -> Vec<T> {
        let mut st = self.state.lock().unwrap();
        loop {
            if !st.items.is_empty() {
                break;
            }
            if st.closed {
                return Vec::new();
            }
            st = self.not_empty.wait(st).unwrap();
        }
        let oldest = st.items.front().map(|&(t, _)| t).expect("loop above ensures non-empty");
        let deadline = oldest + max_wait;
        let mut batch = Vec::new();
        loop {
            while batch.len() < max_n {
                match st.items.pop_front() {
                    Some((_, item)) => batch.push(item),
                    None => break,
                }
            }
            self.not_full.notify_all();
            if batch.len() >= max_n || st.closed {
                break;
            }
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (guard, _timeout) = self.not_empty.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
        batch
    }

    fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }
}

/// What a serve run measured. Responses are sorted by request id, so
/// `responses[i]` answers the load generator's example `i % split.len()`.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub opts: ServeOptions,
    pub wallclock: Duration,
    pub responses: Vec<Response>,
    /// Every batch size the batcher shipped, in ship order.
    pub batch_sizes: Vec<usize>,
    /// Misclassified requests (predictions vs the split's labels).
    pub errors: usize,
    /// Packed-cache build events summed over all workers — with the
    /// integer domain on this is `workers × weight layers` (one prepack
    /// per worker at startup, zero per-request re-packs), and 0 when
    /// the integer domain is off.
    pub weight_pack_builds: u64,
    /// GEMM lowering outcomes summed over every worker's forward sites:
    /// proof of *which* kernel served the requests. With the integer
    /// domain on, every dispatch should land in `int` or `split` and
    /// `simulated()` should be 0; with it off, everything is `disabled`.
    pub int_gemm_dispatch: ops::GemmSiteCounts,
}

impl ServeReport {
    /// Latency percentile over all requests (p in [0, 1]).
    pub fn latency_percentile(&self, p: f64) -> Duration {
        let mut sorted: Vec<f64> =
            self.responses.iter().map(|r| r.latency.as_secs_f64()).collect();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = sorted.len();
        Duration::from_secs_f64(sorted[((p * (n - 1) as f64).round() as usize).min(n - 1)])
    }

    pub fn throughput_rps(&self) -> f64 {
        self.responses.len() as f64 / self.wallclock.as_secs_f64().max(1e-12)
    }

    pub fn mean_fill(&self) -> f64 {
        self.batch_sizes.iter().sum::<usize>() as f64 / self.batch_sizes.len().max(1) as f64
    }

    pub fn max_fill(&self) -> usize {
        self.batch_sizes.iter().copied().max().unwrap_or(0)
    }

    pub fn error_rate(&self) -> f64 {
        self.errors as f64 / self.responses.len().max(1) as f64
    }

    /// The report as a metric/value [`Table`] — printed by `lpdnn serve`
    /// and persisted as versioned JSON (`BENCH_serve.json`) via
    /// [`Table::to_json`].
    pub fn table(&self) -> Table {
        let ms = |d: Duration| format!("{:.3}", d.as_secs_f64() * 1e3);
        let mut t = Table::new(&["metric", "value"]);
        let mut row = |k: &str, v: String| t.row(&[k.to_string(), v]);
        row("requests", self.responses.len().to_string());
        if self.opts.open_rate > 0.0 {
            row("open_rate_rps", format!("{:.1}", self.opts.open_rate));
        } else {
            row("concurrency", self.opts.concurrency.to_string());
        }
        row("workers", self.opts.workers.to_string());
        row("max_batch", self.opts.max_batch.to_string());
        row("max_wait_us", self.opts.max_wait.as_micros().to_string());
        row("int_domain", self.opts.int_domain.to_string());
        row("fused", self.opts.fused.to_string());
        row("weight_packs", self.weight_pack_builds.to_string());
        let d = &self.int_gemm_dispatch;
        row(
            "int_gemm_dispatch",
            format!("int={} split={} simulated={}", d.int, d.split, d.simulated()),
        );
        row("batches", self.batch_sizes.len().to_string());
        row("batch_fill_mean", format!("{:.2}", self.mean_fill()));
        row("batch_fill_max", self.max_fill().to_string());
        row("latency_p50_ms", ms(self.latency_percentile(0.50)));
        row("latency_p95_ms", ms(self.latency_percentile(0.95)));
        row("latency_p99_ms", ms(self.latency_percentile(0.99)));
        row("throughput_rps", format!("{:.1}", self.throughput_rps()));
        row("test_error", format!("{:.6}", self.error_rate()));
        t
    }
}

/// The [`StepOptions`] a serve run evaluates under: deterministic
/// half-away rounding, float16 simulation per the checkpoint, kernel
/// selection per the serve flags. `tests/serve.rs` uses the same
/// options for its direct single-example reference passes.
pub fn eval_options(restored: &Restored, opts: &ServeOptions) -> StepOptions {
    StepOptions {
        mode: RoundMode::HalfAway,
        half: restored.half,
        dropout: None,
        fused: opts.fused,
        conv_direct: false,
        int_domain: opts.int_domain,
        dp_workers: 1, // eval never shards; serve parallelism is its own pool
    }
}

/// One inference worker's whole life: build a private [`Network`]
/// (pre-packing integer operands when the integer domain is on), answer
/// batches until the batch queue closes, and return this worker's
/// packed-cache build count plus its GEMM lowering-outcome counters
/// (all forward sites merged). Shared by the closed-loop and open-loop
/// drivers — the load generator changes, the serving side does not.
fn worker_loop(
    restored: &Restored,
    params: &Params,
    step_opts: &StepOptions,
    batch_q: &BoundedQueue<Vec<Request>>,
    in_dims: &[usize],
) -> (u64, ops::GemmSiteCounts) {
    // restore() already validated the topology, so this only fails on
    // resource exhaustion; panicking beats leaving producers parked on
    // unfulfillable slots
    let net =
        Network::from_topology_shaped(&restored.spec, restored.in_shape, restored.n_classes)
            .expect("serve worker: network construction");
    if step_opts.int_domain {
        // weights are static at inference time: pack every slab once
        // per worker, here, so no request ever pays for packing
        net.prepack_int_operands(params, &restored.ctrl);
    }
    let n_classes = restored.n_classes;
    while let Some(batch) = batch_q.pop() {
        let n = batch.len();
        let mut dims = vec![n];
        dims.extend_from_slice(in_dims);
        let mut xdata = Vec::with_capacity(n * restored.in_shape.len());
        for req in &batch {
            xdata.extend_from_slice(&req.example);
        }
        let x = Tensor::from_vec(&dims, xdata);
        let logits = net.eval_logits_opt(params, &x, &restored.ctrl, step_opts);
        let preds = ops::argmax_rows(&logits);
        for (i, req) in batch.into_iter().enumerate() {
            req.slot.fulfill(Response {
                id: req.id,
                logits: logits.data()[i * n_classes..(i + 1) * n_classes].to_vec(),
                pred: preds[i],
                latency: req.submitted.elapsed(),
            });
        }
    }
    // read after the drain, so an (unwanted) steady-state re-pack shows
    // up in the count, not just in the latency tail
    let mut dispatch = ops::GemmSiteCounts::default();
    for counts in net.int_gemm_sites().values() {
        dispatch.merge(counts);
    }
    (net.weight_pack_builds(), dispatch)
}

/// Shared request-shape validation for both serve drivers.
fn validate_serve(
    restored: &Restored,
    params: &Params,
    split: &Split,
    opts: &ServeOptions,
) -> crate::Result<()> {
    ensure!(opts.requests > 0, "serve: --requests must be > 0");
    ensure!(opts.workers > 0, "serve: --workers must be > 0");
    ensure!(opts.max_batch > 0, "serve: --max-batch must be > 0");
    ensure!(!split.is_empty(), "serve: the example split is empty");
    ensure!(
        split.example_len() == restored.in_shape.len(),
        "serve: split examples carry {} values but the network input {} wants {}",
        split.example_len(),
        restored.in_shape,
        restored.in_shape.len()
    );
    ensure!(
        params.len() == restored.model.params.len(),
        "serve: {} parameter tensors for a model with {}",
        params.len(),
        restored.model.params.len()
    );
    // fail on the caller's thread if the topology cannot build (workers
    // would otherwise leave producers blocked on their slots)
    let _ = Network::from_topology_shaped(&restored.spec, restored.in_shape, restored.n_classes)?;
    Ok(())
}

/// Run the serve pipeline closed-loop against a restored checkpoint:
/// `opts.requests` requests cycling through `split`'s examples, issued
/// by `opts.concurrency` producers, batched and answered by
/// `opts.workers` workers. Returns per-request responses plus latency /
/// throughput / batch-fill measurements.
pub fn serve_closed_loop(
    restored: &Restored,
    params: Arc<Params>,
    split: &Split,
    opts: &ServeOptions,
) -> crate::Result<ServeReport> {
    ensure!(opts.concurrency > 0, "serve: --concurrency must be > 0");
    validate_serve(restored, &params, split, opts)?;

    let step_opts = eval_options(restored, opts);
    let request_q: BoundedQueue<Request> = BoundedQueue::new(opts.queue_cap);
    let batch_q: BoundedQueue<Vec<Request>> = BoundedQueue::new(opts.workers * 2);
    let next_id = AtomicUsize::new(0);
    let weight_packs = AtomicU64::new(0);
    let gemm_dispatch = Mutex::new(ops::GemmSiteCounts::default());
    let in_dims = restored.in_shape.dims();

    let t0 = Instant::now();
    let (mut responses, batch_sizes) = std::thread::scope(|s| {
        let worker_handles: Vec<_> = (0..opts.workers)
            .map(|_| {
                let params = Arc::clone(&params);
                let step_opts = &step_opts;
                let batch_q = &batch_q;
                let restored = &restored;
                let in_dims = &in_dims;
                let weight_packs = &weight_packs;
                let gemm_dispatch = &gemm_dispatch;
                s.spawn(move || {
                    let (builds, dispatch) =
                        worker_loop(restored, &params, step_opts, batch_q, in_dims);
                    weight_packs.fetch_add(builds, Ordering::Relaxed);
                    gemm_dispatch.lock().expect("serve dispatch tally").merge(&dispatch);
                })
            })
            .collect();

        let batcher = s.spawn(|| {
            let mut fills = Vec::new();
            loop {
                let batch = request_q.pop_batch(opts.max_batch, opts.max_wait);
                if batch.is_empty() {
                    break; // closed and drained
                }
                fills.push(batch.len());
                if !batch_q.push(batch) {
                    break;
                }
            }
            batch_q.close();
            fills
        });

        let producer_handles: Vec<_> = (0..opts.concurrency)
            .map(|_| {
                let request_q = &request_q;
                let next_id = &next_id;
                s.spawn(move || {
                    let mut got = Vec::new();
                    loop {
                        let id = next_id.fetch_add(1, Ordering::Relaxed);
                        if id >= opts.requests {
                            break;
                        }
                        let slot = Arc::new(Slot::default());
                        let accepted = request_q.push(Request {
                            id,
                            example: split.example(id % split.len()).to_vec(),
                            submitted: Instant::now(),
                            slot: Arc::clone(&slot),
                        });
                        if !accepted {
                            break;
                        }
                        got.push(slot.wait());
                    }
                    got
                })
            })
            .collect();

        let mut responses = Vec::with_capacity(opts.requests);
        for h in producer_handles {
            responses.extend(h.join().expect("serve producer panicked"));
        }
        request_q.close();
        let batch_sizes = batcher.join().expect("serve batcher panicked");
        for h in worker_handles {
            h.join().expect("serve worker panicked");
        }
        (responses, batch_sizes)
    });
    let wallclock = t0.elapsed();

    responses.sort_by_key(|r| r.id);
    if responses.len() != opts.requests {
        bail!("serve: {} of {} requests were answered", responses.len(), opts.requests);
    }
    let errors = responses
        .iter()
        .filter(|r| r.pred != split.labels[r.id % split.len()])
        .count();
    Ok(ServeReport {
        opts: opts.clone(),
        wallclock,
        responses,
        batch_sizes,
        errors,
        weight_pack_builds: weight_packs.load(Ordering::Relaxed),
        int_gemm_dispatch: *gemm_dispatch.lock().expect("serve dispatch tally"),
    })
}

/// Cumulative Poisson arrival offsets: `n` inter-arrival gaps drawn
/// i.i.d. exponential with mean `1/rate` from a seeded [`Pcg32`], summed
/// into submit times relative to the run's start. Deterministic: the
/// same `(rate, n, seed)` always yields the same schedule, so open-loop
/// serve benches are reproducible modulo OS scheduling.
pub fn poisson_schedule(rate: f64, n: usize, seed: u64) -> Vec<Duration> {
    assert!(rate > 0.0 && rate.is_finite(), "poisson_schedule: rate must be positive");
    let mut rng = Pcg32::seeded(seed);
    let mut t = 0.0f64;
    (0..n)
        .map(|_| {
            // inverse-CDF sample; 1 - u is in (0, 1] so ln() is finite
            let u = rng.uniform() as f64;
            t += -(1.0 - u).ln() / rate;
            Duration::from_secs_f64(t)
        })
        .collect()
}

/// Run the serve pipeline under **open-loop** Poisson load: one
/// generator thread submits `opts.requests` requests at the seeded
/// schedule's absolute times ([`poisson_schedule`] with
/// `opts.open_rate` / `opts.open_seed`), *without* waiting for earlier
/// responses. Unlike the closed loop — whose producers can never have
/// more than `concurrency` requests in flight, so a saturated server
/// just slows the arrival process down — open-loop arrivals keep
/// coming on schedule, and the latency percentiles include the honest
/// queueing delay of a server that falls behind.
pub fn serve_open_loop(
    restored: &Restored,
    params: Arc<Params>,
    split: &Split,
    opts: &ServeOptions,
) -> crate::Result<ServeReport> {
    ensure!(
        opts.open_rate > 0.0 && opts.open_rate.is_finite(),
        "serve: --open-rate must be > 0 (requests/sec) for the open loop"
    );
    validate_serve(restored, &params, split, opts)?;
    let schedule = poisson_schedule(opts.open_rate, opts.requests, opts.open_seed);

    let step_opts = eval_options(restored, opts);
    let request_q: BoundedQueue<Request> = BoundedQueue::new(opts.queue_cap);
    let batch_q: BoundedQueue<Vec<Request>> = BoundedQueue::new(opts.workers * 2);
    let weight_packs = AtomicU64::new(0);
    let gemm_dispatch = Mutex::new(ops::GemmSiteCounts::default());
    let in_dims = restored.in_shape.dims();

    let t0 = Instant::now();
    let (mut responses, batch_sizes) = std::thread::scope(|s| {
        let worker_handles: Vec<_> = (0..opts.workers)
            .map(|_| {
                let params = Arc::clone(&params);
                let step_opts = &step_opts;
                let batch_q = &batch_q;
                let restored = &restored;
                let in_dims = &in_dims;
                let weight_packs = &weight_packs;
                let gemm_dispatch = &gemm_dispatch;
                s.spawn(move || {
                    let (builds, dispatch) =
                        worker_loop(restored, &params, step_opts, batch_q, in_dims);
                    weight_packs.fetch_add(builds, Ordering::Relaxed);
                    gemm_dispatch.lock().expect("serve dispatch tally").merge(&dispatch);
                })
            })
            .collect();

        let batcher = s.spawn(|| {
            let mut fills = Vec::new();
            loop {
                let batch = request_q.pop_batch(opts.max_batch, opts.max_wait);
                if batch.is_empty() {
                    break; // closed and drained
                }
                fills.push(batch.len());
                if !batch_q.push(batch) {
                    break;
                }
            }
            batch_q.close();
            fills
        });

        // the load generator: submit on the Poisson clock, collect
        // every response slot, and only then wait on them — submission
        // never blocks on a response, which is what "open loop" means
        let generator = s.spawn(|| {
            let mut slots = Vec::with_capacity(opts.requests);
            for (id, due) in schedule.iter().enumerate() {
                let due_at = t0 + *due;
                let now = Instant::now();
                if due_at > now {
                    std::thread::sleep(due_at - now);
                }
                let slot = Arc::new(Slot::default());
                // stamp BEFORE the (possibly blocking) push: time spent
                // against a full request queue is queueing delay the
                // percentiles must report
                let accepted = request_q.push(Request {
                    id,
                    example: split.example(id % split.len()).to_vec(),
                    submitted: Instant::now(),
                    slot: Arc::clone(&slot),
                });
                if !accepted {
                    break;
                }
                slots.push(slot);
            }
            request_q.close();
            slots.into_iter().map(|sl| sl.wait()).collect::<Vec<_>>()
        });

        let responses = generator.join().expect("serve generator panicked");
        let batch_sizes = batcher.join().expect("serve batcher panicked");
        for h in worker_handles {
            h.join().expect("serve worker panicked");
        }
        (responses, batch_sizes)
    });
    let wallclock = t0.elapsed();

    responses.sort_by_key(|r| r.id);
    if responses.len() != opts.requests {
        bail!("serve: {} of {} requests were answered", responses.len(), opts.requests);
    }
    let errors = responses
        .iter()
        .filter(|r| r.pred != split.labels[r.id % split.len()])
        .count();
    Ok(ServeReport {
        opts: opts.clone(),
        wallclock,
        responses,
        batch_sizes,
        errors,
        weight_pack_builds: weight_packs.load(Ordering::Relaxed),
        int_gemm_dispatch: *gemm_dispatch.lock().expect("serve dispatch tally"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn queue_round_trips_in_order() {
        let q: BoundedQueue<usize> = BoundedQueue::new(4);
        assert!(q.push(1) && q.push(2) && q.push(3));
        assert_eq!(q.pop(), Some(1));
        let batch = q.pop_batch(8, Duration::ZERO);
        assert_eq!(batch, vec![2, 3]);
        q.close();
        assert_eq!(q.pop(), None);
        assert!(q.pop_batch(8, Duration::ZERO).is_empty());
        assert!(!q.push(4), "push after close must be refused");
    }

    #[test]
    fn pop_batch_caps_at_max_n() {
        let q: BoundedQueue<usize> = BoundedQueue::new(16);
        for i in 0..10 {
            assert!(q.push(i));
        }
        let batch = q.pop_batch(4, Duration::ZERO);
        assert_eq!(batch, vec![0, 1, 2, 3]);
        assert_eq!(q.pop_batch(4, Duration::ZERO), vec![4, 5, 6, 7]);
    }

    #[test]
    fn push_blocks_on_a_full_queue_until_space() {
        let q = Arc::new(BoundedQueue::new(1));
        assert!(q.push(0usize));
        let q2 = Arc::clone(&q);
        let h = thread::spawn(move || q2.push(1));
        thread::sleep(Duration::from_millis(10));
        assert_eq!(q.pop(), Some(0));
        assert!(h.join().unwrap());
        assert_eq!(q.pop(), Some(1));
    }

    #[test]
    fn pop_batch_waits_out_the_deadline_for_more_items() {
        let q = Arc::new(BoundedQueue::new(8));
        assert!(q.push(0usize));
        let q2 = Arc::clone(&q);
        let h = thread::spawn(move || {
            thread::sleep(Duration::from_millis(5));
            q2.push(1)
        });
        // generous deadline: the second item must make it into the batch
        let batch = q.pop_batch(2, Duration::from_secs(5));
        assert_eq!(batch, vec![0, 1]);
        assert!(h.join().unwrap());
    }

    #[test]
    fn pop_batch_ships_an_already_aged_item_without_further_waiting() {
        let q: BoundedQueue<usize> = BoundedQueue::new(4);
        assert!(q.push(7));
        thread::sleep(Duration::from_millis(60));
        let t = Instant::now();
        let batch = q.pop_batch(8, Duration::from_millis(50));
        assert_eq!(batch, vec![7]);
        // the item aged past max_wait before the batcher got to it, so
        // the batch must ship immediately; the old pop-time deadline
        // held it open for another full max_wait here
        assert!(t.elapsed() < Duration::from_millis(40), "shipped after {:?}", t.elapsed());
    }

    /// The regression the enqueue-time stamps fix: under a slow-drain
    /// batcher, a request's queue residency is bounded by roughly
    /// `max_wait` + the batcher's absence, NOT by absence + `max_wait`
    /// *again* (the old pop-time deadline restarted the clock).
    #[test]
    fn queue_residency_is_bounded_by_max_wait_under_slow_drain() {
        let q: Arc<BoundedQueue<Instant>> = Arc::new(BoundedQueue::new(16));
        let q2 = Arc::clone(&q);
        let producer = thread::spawn(move || {
            for _ in 0..4 {
                assert!(q2.push(Instant::now()));
                thread::sleep(Duration::from_millis(30));
            }
        });
        // the batcher is away for ~100ms while requests queue up
        thread::sleep(Duration::from_millis(100));
        let mut residencies = Vec::new();
        while residencies.len() < 4 {
            for stamp in q.pop_batch(100, Duration::from_millis(100)) {
                residencies.push(stamp.elapsed());
            }
        }
        producer.join().unwrap();
        let worst = residencies.iter().max().unwrap();
        // oldest item: ~100ms old at first pop, deadline already spent
        // → ships at once (~100ms residency). The old code waited until
        // pop + max_wait → ~200ms. The 160ms bound splits the two with
        // scheduling slack on both sides.
        assert!(*worst < Duration::from_millis(160), "worst residency {worst:?}");
    }

    #[test]
    fn close_unblocks_waiting_consumers() {
        let q: Arc<BoundedQueue<usize>> = Arc::new(BoundedQueue::new(4));
        let q2 = Arc::clone(&q);
        let h = thread::spawn(move || q2.pop());
        thread::sleep(Duration::from_millis(5));
        q.close();
        assert_eq!(h.join().unwrap(), None);
    }

    #[test]
    fn slot_rendezvous_delivers_the_response() {
        let slot = Arc::new(Slot::default());
        let s2 = Arc::clone(&slot);
        let h = thread::spawn(move || {
            s2.fulfill(Response {
                id: 7,
                logits: vec![0.0, 1.0],
                pred: 1,
                latency: Duration::from_millis(3),
            });
        });
        let r = slot.wait();
        h.join().unwrap();
        assert_eq!(r.id, 7);
        assert_eq!(r.pred, 1);
    }

    #[test]
    fn poisson_schedule_is_seed_deterministic_and_monotone() {
        let a = poisson_schedule(500.0, 64, 42);
        let b = poisson_schedule(500.0, 64, 42);
        assert_eq!(a, b, "same (rate, n, seed) must give the same schedule");
        let c = poisson_schedule(500.0, 64, 43);
        assert_ne!(a, c, "a different seed must give a different schedule");
        assert_eq!(a.len(), 64);
        for w in a.windows(2) {
            assert!(w[1] > w[0], "arrival offsets must be strictly increasing");
        }
        // mean inter-arrival ~ 1/rate: 64 exponential draws at 500 rps
        // land well within [16ms, 1s] total with overwhelming margin
        let total = a.last().unwrap().as_secs_f64();
        assert!(total > 0.016 && total < 1.0, "total {total}s at 500 rps");
    }

    #[test]
    fn open_loop_report_table_carries_the_rate() {
        let opts = ServeOptions { requests: 1, open_rate: 250.0, ..Default::default() };
        let report = ServeReport {
            opts,
            wallclock: Duration::from_millis(4),
            responses: vec![Response {
                id: 0,
                logits: vec![0.0, 1.0],
                pred: 1,
                latency: Duration::from_millis(2),
            }],
            batch_sizes: vec![1],
            errors: 0,
            weight_pack_builds: 0,
            int_gemm_dispatch: ops::GemmSiteCounts::default(),
        };
        let json = report.table().to_json().to_string_pretty();
        assert!(json.contains("open_rate_rps"), "{json}");
        assert!(!json.contains("\"concurrency\""), "{json}");
    }

    #[test]
    fn report_percentiles_and_table() {
        let opts = ServeOptions { requests: 4, ..Default::default() };
        let responses: Vec<Response> = (0..4)
            .map(|i| Response {
                id: i,
                logits: vec![0.0; 10],
                pred: 0,
                latency: Duration::from_millis((i + 1) as u64),
            })
            .collect();
        let report = ServeReport {
            opts,
            wallclock: Duration::from_millis(8),
            responses,
            batch_sizes: vec![2, 2],
            errors: 1,
            weight_pack_builds: 6,
            int_gemm_dispatch: ops::GemmSiteCounts {
                int: 8,
                split: 2,
                ..Default::default()
            },
        };
        assert_eq!(report.latency_percentile(0.0), Duration::from_millis(1));
        assert_eq!(report.latency_percentile(1.0), Duration::from_millis(4));
        assert!(report.latency_percentile(0.5) <= report.latency_percentile(0.99));
        assert!((report.throughput_rps() - 500.0).abs() < 1.0);
        assert_eq!(report.max_fill(), 2);
        assert!((report.mean_fill() - 2.0).abs() < 1e-12);
        assert!((report.error_rate() - 0.25).abs() < 1e-12);
        let json = report.table().to_json().to_string_pretty();
        let doc = crate::config::json::parse(&json).expect("table json parses");
        assert_eq!(doc.get("version").unwrap().as_usize().unwrap(), 1);
        let rows = doc.get("rows").unwrap().as_array().unwrap();
        let metric = |name: &str| {
            rows.iter()
                .find(|r| r.get("metric").unwrap().as_str().unwrap() == name)
                .unwrap_or_else(|| panic!("row {name} missing"))
                .get("value")
                .unwrap()
                .as_str()
                .unwrap()
                .to_string()
        };
        assert_eq!(metric("requests"), "4");
        assert_eq!(metric("weight_packs"), "6");
        assert_eq!(metric("int_gemm_dispatch"), "int=8 split=2 simulated=0");
        // n=4: p50 index = round(0.5 * 3) = 2 → the 3ms sample
        assert_eq!(metric("latency_p50_ms"), "3.000");
        assert_eq!(metric("latency_p99_ms"), "4.000");
        assert_eq!(metric("throughput_rps"), "500.0");
        assert_eq!(metric("test_error"), "0.250000");
    }
}
