//! From-scratch error substrate (the offline build has no `anyhow`).
//!
//! A deliberately tiny mirror of the `anyhow` surface this crate actually
//! uses: an opaque [`Error`] carrying a context chain, a [`Result`] alias,
//! the [`Context`] extension trait for `Result`/`Option`, and the
//! `err!` / `bail!` / `ensure!` macros (exported at the crate root).
//! `{e}` displays the outermost message; `{e:#}` displays the full
//! chain joined with `: ` (matching how `main.rs` reports failures).
//!
//! Any `std::error::Error` converts into [`Error`] via `?` (the blanket
//! `From` below), so `io::Error`, the config parsers' typed errors and —
//! under the `pjrt` feature — `xla::Error` all flow through unchanged.

use std::fmt;

/// An opaque error: a chain of human-readable messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a single message.
    pub fn msg(msg: impl fmt::Display) -> Error {
        Error { chain: vec![msg.to_string()] }
    }

    /// Prepend a higher-level context message.
    pub fn context(mut self, ctx: impl fmt::Display) -> Error {
        self.chain.insert(0, ctx.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> &[String] {
        &self.chain
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or("unknown error"))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Debug (what `unwrap`/`expect` print) shows the full chain.
        write!(f, "{}", self.chain.join(": "))
    }
}

// NOTE: `Error` intentionally does NOT implement `std::error::Error`;
// that is what makes this blanket conversion coherent (same trick as
// `anyhow`): every concrete error type flows through `?` into `Error`.
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Context-attachment extension for `Result` and `Option`.
pub trait Context<T> {
    /// Wrap the error (or `None`) with a context message.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    /// Wrap with a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into().context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`](crate::error::Error) from a format string.
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => { $crate::error::Error::msg(format!($($arg)*)) };
}

/// Return early with a formatted error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return Err($crate::err!($($arg)*)) };
}

/// Bail unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "no such file")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e = Error::msg("inner").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner");
    }

    #[test]
    fn std_errors_convert_via_question_mark() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = f().unwrap_err();
        assert!(format!("{e}").contains("no such file"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading config").unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert!(format!("{e:#}").contains("no such file"));

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(format!("{e}"), "missing key");
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: i32) -> Result<i32> {
            crate::ensure!(x >= 0, "negative input {x}");
            if x > 100 {
                crate::bail!("too large: {x}");
            }
            Ok(x)
        }
        assert!(f(5).is_ok());
        assert!(format!("{}", f(-1).unwrap_err()).contains("negative input"));
        assert!(format!("{}", f(101).unwrap_err()).contains("too large"));
        let e = crate::err!("code {}", 7);
        assert_eq!(format!("{e}"), "code 7");
    }
}
