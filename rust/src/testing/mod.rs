//! Quickcheck-lite: deterministic property testing without external crates.
//!
//! The offline build environment ships no proptest/quickcheck, so this is a
//! small from-scratch harness: a seeded [`Gen`] (SplitMix64 core) plus a
//! [`forall`] runner that executes a property over `N` generated cases and
//! reports the failing case index + seed so a failure reproduces exactly.
//!
//! It also hosts the shared deterministic fixtures (`gen_quantizer`,
//! `gen_signal`, the tiny maxout-MLP state builders) that the quantizer
//! property tests, the fused-GEMM parity suite and the golden unit tests
//! all build their cases from — one place to widen the tested regimes.
//!
//! ```no_run
//! // (no_run: rustdoc test binaries don't inherit the xla rpath flags)
//! use lpdnn::testing::{forall, Gen};
//! forall("abs is non-negative", |g: &mut Gen| {
//!     let x = g.f32_range(-100.0, 100.0);
//!     assert!(x.abs() >= 0.0);
//! });
//! ```

use std::panic::{catch_unwind, AssertUnwindSafe};

use crate::arith::{FixedFormat, Quantizer, RoundMode};
use crate::config::{ConvStageSpec, TopologySpec};
use crate::golden::{MlpShape, Params};
use crate::runtime::ModelInfo;
use crate::tensor::{init::InitSpec, ops, Pcg32, Shape, Tensor};

/// Number of cases per property (override with env `LPDNN_PROP_CASES`).
pub const DEFAULT_CASES: usize = 200;

/// Deterministic random generator for property tests (SplitMix64).
pub struct Gen {
    state: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Gen { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15) }
    }

    /// Next raw 64-bit value (SplitMix64 step).
    pub fn u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    pub fn u32(&mut self) -> u32 {
        (self.u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)`.
    pub fn f64_unit(&mut self) -> f64 {
        (self.u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (self.f64_unit() as f32) * (hi - lo)
    }

    /// Uniform i32 in `[lo, hi]` (inclusive).
    pub fn i32_range(&mut self, lo: i32, hi: i32) -> i32 {
        debug_assert!(lo <= hi);
        let span = (hi as i64 - lo as i64 + 1) as u64;
        (lo as i64 + (self.u64() % span) as i64) as i32
    }

    /// Uniform usize in `[lo, hi]` (inclusive).
    pub fn usize_range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + (self.u64() % (hi as u64 - lo as u64 + 1)) as usize
    }

    pub fn bool(&mut self) -> bool {
        self.u64() & 1 == 1
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_range(0, xs.len() - 1)]
    }

    /// A vector of f32 drawn from `[lo, hi)` with random length in
    /// `[min_len, max_len]`.
    pub fn vec_f32(&mut self, min_len: usize, max_len: usize, lo: f32, hi: f32) -> Vec<f32> {
        let n = self.usize_range(min_len, max_len);
        (0..n).map(|_| self.f32_range(lo, hi)).collect()
    }

    /// Roughly normal sample (sum of uniforms, Irwin–Hall with 12 terms).
    pub fn f32_normal(&mut self, mean: f32, sd: f32) -> f32 {
        let s: f64 = (0..12).map(|_| self.f64_unit()).sum::<f64>() - 6.0;
        mean + sd * s as f32
    }
}

fn n_cases() -> usize {
    std::env::var("LPDNN_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(DEFAULT_CASES)
}

/// Run `prop` over `n_cases()` deterministic generators. On failure, panics
/// with the case index and per-case seed so the case replays in isolation:
/// `Gen::new(seed)` reproduces the failing inputs exactly.
pub fn forall<F: Fn(&mut Gen)>(name: &str, prop: F) {
    forall_seeded(name, 0xC0FF_EE00, prop)
}

/// [`forall`] with an explicit base seed (distinct properties in one test
/// fn should use different seeds to decorrelate).
pub fn forall_seeded<F: Fn(&mut Gen)>(name: &str, base_seed: u64, prop: F) {
    for case in 0..n_cases() {
        let seed = base_seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let result = catch_unwind(AssertUnwindSafe(|| {
            let mut g = Gen::new(seed);
            prop(&mut g);
        }));
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| err.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!(
                "property '{name}' failed at case {case} (replay: Gen::new({seed:#x})): {msg}"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Shared fixtures
// ---------------------------------------------------------------------------

/// All four rounding modes — the ablation/parity matrices iterate this.
pub const ROUND_MODES: [RoundMode; 4] = [
    RoundMode::HalfAway,
    RoundMode::HalfEven,
    RoundMode::Truncate,
    RoundMode::Stochastic,
];

/// A spread of fixed formats covering the regimes the paper's sweeps
/// cross: wide storage, the canonical 10.3 computation grid, narrow
/// widths near the error cliff, and negative-radix (all-fractional)
/// gradient formats.
pub fn format_grid() -> Vec<FixedFormat> {
    vec![
        FixedFormat::new(20, 5),
        FixedFormat::new(12, 0),
        FixedFormat::new(10, 3),
        FixedFormat::new(6, 1),
        FixedFormat::new(8, -2),
    ]
}

/// A random (never-passthrough) quantizer: random format + rounding mode.
pub fn gen_quantizer(g: &mut Gen) -> Quantizer {
    let mut q =
        Quantizer::from_format(FixedFormat::new(g.i32_range(2, 24), g.i32_range(-4, 8)));
    q.mode = *g.choose(&ROUND_MODES);
    q
}

/// Signal data for `q`: values spanning well inside the representable
/// range *and* beyond `maxv`, so clipping and the overflow counters are
/// always exercised. Falls back to a small span for passthrough.
pub fn gen_signal(g: &mut Gen, q: &Quantizer, min_len: usize, max_len: usize) -> Vec<f32> {
    let span = if q.is_passthrough() { 4.0 } else { 2.5 * q.maxv };
    g.vec_f32(min_len, max_len, -span, span)
}

/// The tiny maxout-MLP shape the golden/backend unit tests train.
pub fn tiny_mlp() -> MlpShape {
    MlpShape { d_in: 12, units: 8, k: 2, n_classes: 4 }
}

/// Deterministic (params, velocities) for `s` in manifest order
/// (w0 b0 w1 b1 w2 b2): Glorot-uniform weights, zero biases/velocities.
pub fn mlp_state(s: MlpShape, seed: u64) -> (Params, Params) {
    let mut rng = Pcg32::seeded(seed);
    let mk = |shape: &[usize], rng: &mut Pcg32, fan_in: usize, fan_out: usize| {
        InitSpec::GlorotUniform { fan_in, fan_out }.realize(shape, rng)
    };
    let params = vec![
        mk(&[s.k, s.d_in, s.units], &mut rng, s.d_in, s.units),
        Tensor::zeros(&[s.k, s.units]),
        mk(&[s.k, s.units, s.units], &mut rng, s.units, s.units),
        Tensor::zeros(&[s.k, s.units]),
        mk(&[s.units, s.n_classes], &mut rng, s.units, s.n_classes),
        Tensor::zeros(&[s.n_classes]),
    ];
    let vels = params.iter().map(|p| Tensor::zeros(p.shape())).collect();
    (params, vels)
}

/// A deterministic `[n, d_in]` normal batch with one-hot labels for `s`.
pub fn mlp_batch(s: MlpShape, n: usize, seed: u64) -> (Tensor, Tensor) {
    let mut rng = Pcg32::seeded(seed);
    let x = Tensor::from_vec(&[n, s.d_in], (0..n * s.d_in).map(|_| rng.normal()).collect());
    let labels: Vec<usize> =
        (0..n).map(|_| rng.below(s.n_classes as u32) as usize).collect();
    (x, ops::one_hot(&labels, s.n_classes))
}

/// The tiny 2-conv-stage + 1-dense maxout topology the conv parity
/// suites train, paired with [`TINY_CONV_SHAPE`]/[`TINY_CONV_CLASSES`].
pub fn tiny_conv_spec() -> TopologySpec {
    TopologySpec::conv_net(
        vec![
            ConvStageSpec { channels: 3, ksize: 3, pool: 2 },
            ConvStageSpec { channels: 4, ksize: 3, pool: 2 },
        ],
        vec![6],
        2,
    )
}

/// Input shape for [`tiny_conv_spec`]: 8×8 two-channel images.
pub const TINY_CONV_SHAPE: Shape = Shape::Spatial { h: 8, w: 8, c: 2 };

/// Class count for [`tiny_conv_spec`] fixtures.
pub const TINY_CONV_CLASSES: usize = 4;

/// Deterministic (params, velocities) for a topology realized against
/// `in_shape` (manifest order, Glorot weights, zero biases/velocities).
pub fn topology_state(
    spec: &TopologySpec,
    in_shape: Shape,
    n_classes: usize,
    seed: u64,
) -> (Params, Params) {
    let info = ModelInfo::from_topology_shaped(spec, &in_shape, n_classes)
        .expect("fixture topology realizes");
    let mut rng = Pcg32::seeded(seed);
    let params: Vec<Tensor> =
        info.params.iter().map(|s| s.init.realize(&s.shape, &mut rng)).collect();
    let vels = params.iter().map(|p| Tensor::zeros(p.shape())).collect();
    (params, vels)
}

/// A deterministic `[n, ...shape.dims()]` normal batch (~10% exact
/// zeros, so the conv kernels' zero fast-paths fire) with one-hot
/// labels.
pub fn spatial_batch(in_shape: Shape, n: usize, n_classes: usize, seed: u64) -> (Tensor, Tensor) {
    let mut rng = Pcg32::seeded(seed);
    let mut dims = vec![n];
    dims.extend(in_shape.dims());
    let x = Tensor::from_vec(
        &dims,
        (0..n * in_shape.len())
            .map(|_| {
                if rng.uniform() < 0.1 {
                    0.0
                } else {
                    rng.normal()
                }
            })
            .collect(),
    );
    let labels: Vec<usize> = (0..n).map(|_| rng.below(n_classes as u32) as usize).collect();
    (x, ops::one_hot(&labels, n_classes))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic() {
        let mut a = Gen::new(7);
        let mut b = Gen::new(7);
        for _ in 0..100 {
            assert_eq!(a.u64(), b.u64());
        }
    }

    #[test]
    fn ranges_respect_bounds() {
        forall("bounds", |g: &mut Gen| {
            let x = g.f32_range(-3.0, 5.0);
            assert!((-3.0..5.0).contains(&x));
            let k = g.i32_range(-7, 7);
            assert!((-7..=7).contains(&k));
            let u = g.usize_range(2, 9);
            assert!((2..=9).contains(&u));
        });
    }

    #[test]
    fn f64_unit_in_unit_interval() {
        let mut g = Gen::new(1);
        for _ in 0..10_000 {
            let u = g.f64_unit();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_has_roughly_right_moments() {
        let mut g = Gen::new(3);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| g.f32_normal(1.0, 2.0)).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!((mean - 1.0).abs() < 0.05, "mean={mean}");
        assert!((var - 4.0).abs() < 0.15, "var={var}");
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failures_report_case_and_seed() {
        forall("always fails", |_g: &mut Gen| panic!("boom"));
    }
}
