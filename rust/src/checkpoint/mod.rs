//! Versioned checkpoint persistence (DESIGN.md §Checkpoint format).
//!
//! A checkpoint captures everything needed to reconstruct a trained
//! [`Network`](crate::golden::Network) **bit-exactly**: the
//! [`TopologySpec`], the arithmetic, the per-group int-bit positions the
//! [`ScaleController`] had adopted by the end of training, and every
//! parameter tensor *on its storage grid*. The on-disk form is a single
//! key-sorted JSON document (the in-repo [`crate::config::json`] codec —
//! `BTreeMap` keys make the serialization deterministic, so checkpoints
//! diff cleanly across commits) with a format-version field and an
//! FNV-1a integrity checksum.
//!
//! Bit-exactness rests on two choices:
//!
//! - **Parameters are stored as `f32::to_bits()` patterns**, not decimal
//!   floats. The JSON number writer prints whole numbers below 1e15 as
//!   exact integers, and every `u32` is such a number — so the payload
//!   round-trips every f32 bit pattern exactly, including `-0.0`,
//!   denormals, and the sign bit the decimal shortest-round-trip path
//!   would be trusted (rather than proven) to keep.
//! - **Scales are stored as int-bit positions, not step values.** The
//!   controller rebuilds each group's [`crate::arith::FixedFormat`] from
//!   `(total_bits from the arithmetic, int_bits from the checkpoint)`,
//!   which is exactly how [`ScaleController::adopt_int_bits`] constructs
//!   formats during training.
//!
//! `lpdnn train --save <path>` writes one; `lpdnn infer --load <path>`
//! and `lpdnn serve --load <path>` restore it. Loading distinguishes
//! four failure modes with distinct, message-carrying errors: corrupted
//! JSON, an unsupported format version, a checksum mismatch, and a
//! topology/dataset shape mismatch (see `tests/checkpoint.rs`).

use std::collections::BTreeMap;

use crate::config::json::{self, Json};
use crate::config::{
    Arithmetic, BackendKind, DataConfig, ExperimentConfig, TopologySpec, TrainConfig,
};
use crate::coordinator::{RunResult, ScaleController};
use crate::data::dataset_shape;
use crate::error::Context;
use crate::runtime::ModelInfo;
use crate::tensor::{Shape, Tensor};
use crate::{bail, ensure};

/// On-disk format version. Bump on any incompatible layout change; the
/// loader rejects versions it does not understand *before* attempting a
/// checksum (the checksum scheme itself is part of the version).
pub const CHECKPOINT_VERSION: usize = 1;

/// Largest integer the JSON number writer round-trips exactly (f64
/// mantissa width). Seeds above this would be silently corrupted.
const JSON_EXACT_MAX: u64 = 1 << 53;

/// A trained model, ready to persist or just loaded from disk.
#[derive(Clone, Debug)]
pub struct Checkpoint {
    /// Experiment name (provenance only).
    pub name: String,
    /// Model label the run was launched with (provenance only; the
    /// embedded [`TopologySpec`] is authoritative).
    pub model: String,
    /// The full topology — restoring never consults the builtin table.
    pub topology: TopologySpec,
    /// Dataset name ("digits" | "clusters" | "cifar_like" | "svhn_like").
    pub dataset: String,
    pub n_train: usize,
    /// Test-set size **after** the trainer's padding to whole eval
    /// batches — stored post-rounding so `infer` regenerates the
    /// identical split (its own `div_ceil` is then the identity).
    pub n_test: usize,
    /// Master seed: dataset generation derives from it.
    pub seed: u64,
    pub arithmetic: Arithmetic,
    /// Per-group adopted int-bit positions, [`ScaleController::int_bits_vec`]
    /// order. For float32/half these are ignored on restore (the
    /// passthrough sentinel must not be rebuilt as a fixed format).
    pub int_bits: Vec<i32>,
    /// Final train-time test error — `lpdnn infer` recomputes the eval
    /// and insists on exact equality (the round-trip bit-identity check).
    pub test_error: f64,
    /// Parameter tensors in manifest order (w0, b0, w1, b1, ...), values
    /// already on their storage grids.
    pub params: Vec<Tensor>,
}

/// Everything [`Checkpoint::restore`] reconstructs besides the raw
/// params: the realized shapes, manifest, and a frozen scale controller.
#[derive(Clone, Debug)]
pub struct Restored {
    pub spec: TopologySpec,
    /// Network input shape (flattened for pure-MLP topologies, spatial
    /// for conv — the same rule the native backend applies).
    pub in_shape: Shape,
    pub n_classes: usize,
    pub model: ModelInfo,
    /// A *fixed* controller carrying the adopted formats. Inference
    /// never ticks it, so even dynamic-arithmetic checkpoints restore to
    /// frozen scales.
    pub ctrl: ScaleController,
    /// Simulate float16 value grids during the forward pass.
    pub half: bool,
}

/// FNV-1a 64-bit over the compact serialization — fast, dependency-free,
/// and plenty for detecting corruption (this is an integrity check, not
/// an authentication scheme).
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Checksum of a checkpoint body (the document *minus* its "checksum"
/// key, serialized compactly — key-sorted maps make this deterministic).
fn checksum(body: BTreeMap<String, Json>) -> String {
    format!("{:016x}", fnv1a64(Json::Object(body).to_string().as_bytes()))
}

/// Arithmetic → JSON, mirroring the key names `ExperimentConfig::from_json`
/// reads (`kind`, `bits_comp`, `bits_up`, ...), so checkpoint files and
/// experiment configs describe arithmetics in the same vocabulary.
fn arithmetic_to_json(a: &Arithmetic) -> Json {
    let mut m = BTreeMap::new();
    let mut put = |k: &str, v: Json| {
        m.insert(k.to_string(), v);
    };
    match a {
        Arithmetic::Float32 => put("kind", Json::Str("float32".into())),
        Arithmetic::Half => put("kind", Json::Str("half".into())),
        Arithmetic::Fixed { bits_comp, bits_up, int_bits } => {
            put("kind", Json::Str("fixed".into()));
            put("bits_comp", Json::Num(f64::from(*bits_comp)));
            put("bits_up", Json::Num(f64::from(*bits_up)));
            put("int_bits", Json::Num(f64::from(*int_bits)));
        }
        Arithmetic::Dynamic {
            bits_comp,
            bits_up,
            max_overflow_rate,
            update_every_examples,
            init_int_bits,
            warmup_steps,
        } => {
            put("kind", Json::Str("dynamic".into()));
            put("bits_comp", Json::Num(f64::from(*bits_comp)));
            put("bits_up", Json::Num(f64::from(*bits_up)));
            put("max_overflow_rate", Json::Num(*max_overflow_rate));
            put("update_every_examples", Json::Num(*update_every_examples as f64));
            put("init_int_bits", Json::Num(f64::from(*init_int_bits)));
            put("warmup_steps", Json::Num(*warmup_steps as f64));
        }
    }
    Json::Object(m)
}

/// JSON → Arithmetic (inverse of [`arithmetic_to_json`]).
fn arithmetic_from_json(j: &Json) -> crate::Result<Arithmetic> {
    let kind = j.get("kind")?.as_str().context("arithmetic kind")?;
    match kind {
        "float32" => Ok(Arithmetic::Float32),
        "half" | "float16" => Ok(Arithmetic::Half),
        "fixed" => Ok(Arithmetic::Fixed {
            bits_comp: j.get("bits_comp")?.as_i64()? as i32,
            bits_up: j.get("bits_up")?.as_i64()? as i32,
            int_bits: j.get("int_bits")?.as_i64()? as i32,
        }),
        "dynamic" => Ok(Arithmetic::Dynamic {
            bits_comp: j.get("bits_comp")?.as_i64()? as i32,
            bits_up: j.get("bits_up")?.as_i64()? as i32,
            max_overflow_rate: j.get("max_overflow_rate")?.as_f64()?,
            update_every_examples: j.get("update_every_examples")?.as_usize()?,
            init_int_bits: j.get("init_int_bits")?.as_i64()? as i32,
            warmup_steps: j.get("warmup_steps")?.as_usize()?,
        }),
        other => bail!("unknown arithmetic kind '{other}' (float32|half|fixed|dynamic)"),
    }
}

impl Checkpoint {
    /// Capture a finished run: the config it was launched with, its
    /// [`RunResult`], and the backend's parameters in manifest order.
    pub fn from_run(
        cfg: &ExperimentConfig,
        result: &RunResult,
        params: Vec<Tensor>,
    ) -> crate::Result<Checkpoint> {
        let topology = match &cfg.topology {
            Some(spec) => spec.clone(),
            None => TopologySpec::builtin(&cfg.model).with_context(|| {
                format!("model '{}' is not a builtin topology; cannot checkpoint", cfg.model)
            })?,
        };
        ensure!(
            cfg.train.seed <= JSON_EXACT_MAX,
            "seed {} exceeds the JSON-exact integer range (2^53); pick a smaller seed to checkpoint",
            cfg.train.seed
        );
        // Store the *padded* test-set size the trainer actually
        // evaluated, so `infer --load` regenerates the identical split.
        let n_test = cfg.data.n_test.div_ceil(topology.eval_batch) * topology.eval_batch;
        Ok(Checkpoint {
            name: cfg.name.clone(),
            model: cfg.model.clone(),
            topology,
            dataset: cfg.data.dataset.clone(),
            n_train: cfg.data.n_train,
            n_test,
            seed: cfg.train.seed,
            arithmetic: cfg.arithmetic.clone(),
            int_bits: result.final_int_bits.clone(),
            test_error: result.test_error,
            params,
        })
    }

    /// The checkpoint as a key-sorted JSON document, checksum included.
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("version".to_string(), Json::Num(CHECKPOINT_VERSION as f64));
        m.insert("name".to_string(), Json::Str(self.name.clone()));
        m.insert("model".to_string(), Json::Str(self.model.clone()));
        m.insert("topology".to_string(), self.topology.to_json());
        let mut data = BTreeMap::new();
        data.insert("dataset".to_string(), Json::Str(self.dataset.clone()));
        data.insert("n_train".to_string(), Json::Num(self.n_train as f64));
        data.insert("n_test".to_string(), Json::Num(self.n_test as f64));
        m.insert("data".to_string(), Json::Object(data));
        m.insert("seed".to_string(), Json::Num(self.seed as f64));
        m.insert("arithmetic".to_string(), arithmetic_to_json(&self.arithmetic));
        m.insert(
            "int_bits".to_string(),
            Json::Array(self.int_bits.iter().map(|&b| Json::Num(f64::from(b))).collect()),
        );
        m.insert("test_error".to_string(), Json::Num(self.test_error));
        let params: Vec<Json> = self
            .params
            .iter()
            .enumerate()
            .map(|(i, t)| {
                let mut p = BTreeMap::new();
                // advisory label matching the manifest's naming scheme
                // (w/b alternate per layer); validation goes by shape
                let kind = if i % 2 == 0 { "w" } else { "b" };
                p.insert("name".to_string(), Json::Str(format!("l{}.{kind}", i / 2)));
                p.insert(
                    "shape".to_string(),
                    Json::Array(t.shape().iter().map(|&d| Json::Num(d as f64)).collect()),
                );
                p.insert(
                    "bits".to_string(),
                    Json::Array(
                        t.data().iter().map(|v| Json::Num(f64::from(v.to_bits()))).collect(),
                    ),
                );
                Json::Object(p)
            })
            .collect();
        m.insert("params".to_string(), Json::Array(params));
        let sum = checksum(m.clone());
        m.insert("checksum".to_string(), Json::Str(sum));
        Json::Object(m)
    }

    /// Parse a checkpoint document: version gate, checksum verification,
    /// then field decoding. Shape validation happens in [`restore`].
    ///
    /// [`restore`]: Checkpoint::restore
    pub fn from_json(doc: &Json) -> crate::Result<Checkpoint> {
        let obj = doc.as_object().context("checkpoint root must be a JSON object")?;
        let version = doc.get("version")?.as_usize()?;
        ensure!(
            version == CHECKPOINT_VERSION,
            "unsupported checkpoint version {version} (this build reads version {CHECKPOINT_VERSION})"
        );
        let stored = doc.get("checksum")?.as_str()?.to_string();
        let mut body = obj.clone();
        body.remove("checksum");
        let computed = checksum(body);
        ensure!(
            stored == computed,
            "checkpoint checksum mismatch: stored {stored}, recomputed {computed} \
             (file corrupted or hand-edited)"
        );

        let data = doc.get("data")?;
        let seed = doc.get("seed")?.as_i64()?;
        ensure!(seed >= 0, "checkpoint seed {seed} is negative");
        let int_bits: Vec<i32> = doc
            .get("int_bits")?
            .as_array()?
            .iter()
            .map(|b| b.as_i64().map(|v| v as i32))
            .collect::<Result<_, _>>()
            .context("int_bits")?;

        let mut params = Vec::new();
        for (i, p) in doc.get("params")?.as_array()?.iter().enumerate() {
            let shape = p.get("shape")?.as_usize_vec().with_context(|| format!("param {i}"))?;
            let bits = p.get("bits")?.as_array().with_context(|| format!("param {i}"))?;
            let mut values = Vec::with_capacity(bits.len());
            for b in bits {
                let v = b.as_f64()?;
                ensure!(
                    v.fract() == 0.0 && (0.0..=f64::from(u32::MAX)).contains(&v),
                    "checkpoint param {i}: {v} is not a u32 f32-bit pattern"
                );
                values.push(f32::from_bits(v as u32));
            }
            let want: usize = shape.iter().product();
            ensure!(
                values.len() == want,
                "checkpoint param {i}: shape {shape:?} wants {want} values, found {}",
                values.len()
            );
            params.push(Tensor::from_vec(&shape, values));
        }

        Ok(Checkpoint {
            name: doc.get("name")?.as_str()?.to_string(),
            model: doc.get("model")?.as_str()?.to_string(),
            topology: TopologySpec::from_json(doc.get("topology")?)
                .context("checkpoint topology")?,
            dataset: data.get("dataset")?.as_str()?.to_string(),
            n_train: data.get("n_train")?.as_usize()?,
            n_test: data.get("n_test")?.as_usize()?,
            seed: seed as u64,
            arithmetic: arithmetic_from_json(doc.get("arithmetic")?)
                .context("checkpoint arithmetic")?,
            int_bits,
            test_error: doc.get("test_error")?.as_f64()?,
            params,
        })
    }

    /// Parse checkpoint text (corrupted JSON is the first distinct
    /// failure mode; everything downstream sees a well-formed document).
    pub fn parse(text: &str) -> crate::Result<Checkpoint> {
        let doc = json::parse(text).context("checkpoint is not valid JSON")?;
        Checkpoint::from_json(&doc)
    }

    /// Write the checkpoint to `path` (pretty-printed — params dominate
    /// the size either way, and pretty files diff and debug better).
    pub fn save(&self, path: &str) -> crate::Result<()> {
        let mut text = self.to_json().to_string_pretty();
        text.push('\n');
        std::fs::write(path, text).with_context(|| format!("writing checkpoint {path}"))
    }

    /// Read + parse a checkpoint file.
    pub fn load(path: &str) -> crate::Result<Checkpoint> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading checkpoint {path}"))?;
        Checkpoint::parse(&text).with_context(|| format!("checkpoint {path}"))
    }

    /// Re-realize the topology against the dataset, validate the stored
    /// state against the resulting manifest (the fourth distinct failure
    /// mode: topology/dataset shape mismatch), and rebuild the frozen
    /// scale controller.
    pub fn restore(&self) -> crate::Result<Restored> {
        self.topology
            .validate()
            .with_context(|| format!("checkpoint topology '{}'", self.topology.name))?;
        let (data_shape, n_classes) = dataset_shape(&self.dataset)?;
        let in_shape =
            if self.topology.conv.is_empty() { data_shape.flattened() } else { data_shape };
        let model = ModelInfo::from_topology_shaped(&self.topology, &in_shape, n_classes)?;
        ensure!(
            self.int_bits.len() == model.n_groups,
            "checkpoint scale table has {} groups but topology '{}' on dataset '{}' yields {} \
             — topology/dataset mismatch",
            self.int_bits.len(),
            self.topology.name,
            self.dataset,
            model.n_groups
        );
        ensure!(
            self.params.len() == model.params.len(),
            "checkpoint carries {} parameter tensors but topology '{}' on dataset '{}' wants {} \
             — topology/dataset mismatch",
            self.params.len(),
            self.topology.name,
            self.dataset,
            model.params.len()
        );
        for (t, spec) in self.params.iter().zip(&model.params) {
            ensure!(
                t.shape() == spec.shape.as_slice(),
                "checkpoint parameter '{}' has shape {:?} but topology '{}' on dataset '{}' \
                 wants {:?} — topology/dataset mismatch",
                spec.name,
                t.shape(),
                self.topology.name,
                self.dataset,
                spec.shape
            );
        }
        let (comp_fmt, up_fmt) = self.arithmetic.initial_formats();
        let mut ctrl = ScaleController::fixed(model.n_groups, comp_fmt, up_fmt);
        // Only fixed-point arithmetics adopt stored scales: float32/half
        // use the passthrough sentinel format (total_bits = 0), which
        // adoption would rebuild as a (degenerate) fixed format.
        if matches!(self.arithmetic, Arithmetic::Fixed { .. } | Arithmetic::Dynamic { .. }) {
            ctrl.adopt_int_bits(&self.int_bits);
        }
        let half = matches!(self.arithmetic, Arithmetic::Half);
        Ok(Restored {
            spec: self.topology.clone(),
            in_shape,
            n_classes,
            model,
            ctrl,
            half,
        })
    }

    /// An [`ExperimentConfig`] equivalent to the checkpointed run for
    /// backend setup: explicit topology, native backend, and the
    /// trainer-facing data/seed fields. Train-schedule fields are
    /// defaults — inference never reads them.
    pub fn to_config(&self) -> ExperimentConfig {
        ExperimentConfig {
            name: self.name.clone(),
            model: self.model.clone(),
            backend: BackendKind::Native,
            topology: Some(self.topology.clone()),
            arithmetic: self.arithmetic.clone(),
            train: TrainConfig { seed: self.seed, ..TrainConfig::default() },
            data: DataConfig {
                dataset: self.dataset.clone(),
                n_train: self.n_train,
                n_test: self.n_test,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_checkpoint(arithmetic: Arithmetic) -> Checkpoint {
        let mut spec = TopologySpec::mlp(vec![6, 5], 2);
        spec.eval_batch = 8;
        spec.train_batch = 4;
        let n_groups = spec.n_layers() * crate::runtime::manifest::N_KINDS;
        // parameter payload exercising the hard bit patterns: -0.0 (the
        // decimal writer would drop the sign), a denormal, and exact grid
        // values
        let w0 = Tensor::from_vec(&[2, 784, 6], vec![0.125; 2 * 784 * 6]);
        let mut b0 = Tensor::zeros(&[2, 6]);
        b0.data_mut()[0] = -0.0;
        b0.data_mut()[1] = f32::from_bits(1); // smallest denormal
        let w1 = Tensor::from_vec(&[2, 6, 5], vec![-0.375; 2 * 6 * 5]);
        let b1 = Tensor::zeros(&[2, 5]);
        let w2 = Tensor::from_vec(&[5, 10], vec![0.5; 50]);
        let b2 = Tensor::zeros(&[10]);
        Checkpoint {
            name: "unit".into(),
            model: "custom".into(),
            topology: spec,
            dataset: "clusters".into(),
            n_train: 64,
            n_test: 16,
            seed: 7,
            arithmetic,
            int_bits: (0..n_groups as i32).map(|g| g % 5 - 2).collect(),
            test_error: 0.171875,
            params: vec![w0, b0, w1, b1, w2, b2],
        }
    }

    fn assert_round_trip(ck: &Checkpoint) {
        let text = ck.to_json().to_string_pretty();
        let back = Checkpoint::parse(&text).expect("round trip");
        assert_eq!(back.name, ck.name);
        assert_eq!(back.model, ck.model);
        assert_eq!(back.topology, ck.topology);
        assert_eq!(back.dataset, ck.dataset);
        assert_eq!(back.n_train, ck.n_train);
        assert_eq!(back.n_test, ck.n_test);
        assert_eq!(back.seed, ck.seed);
        assert_eq!(back.arithmetic, ck.arithmetic);
        assert_eq!(back.int_bits, ck.int_bits);
        assert_eq!(back.test_error.to_bits(), ck.test_error.to_bits());
        assert_eq!(back.params.len(), ck.params.len());
        for (a, b) in back.params.iter().zip(&ck.params) {
            assert_eq!(a.shape(), b.shape());
            let bits_a: Vec<u32> = a.data().iter().map(|v| v.to_bits()).collect();
            let bits_b: Vec<u32> = b.data().iter().map(|v| v.to_bits()).collect();
            assert_eq!(bits_a, bits_b, "param bits must survive the round trip");
        }
    }

    #[test]
    fn round_trips_all_arithmetics_bit_exactly() {
        for arithmetic in [
            Arithmetic::Float32,
            Arithmetic::Half,
            Arithmetic::Fixed { bits_comp: 10, bits_up: 12, int_bits: 1 },
            Arithmetic::Dynamic {
                bits_comp: 10,
                bits_up: 12,
                max_overflow_rate: 0.01,
                update_every_examples: 100,
                init_int_bits: 1,
                warmup_steps: 10,
            },
        ] {
            assert_round_trip(&sample_checkpoint(arithmetic));
        }
    }

    #[test]
    fn restore_rebuilds_manifest_and_adopted_scales() {
        let ck = sample_checkpoint(Arithmetic::Fixed { bits_comp: 10, bits_up: 12, int_bits: 1 });
        let restored = ck.restore().expect("restore");
        assert_eq!(restored.model.params.len(), ck.params.len());
        assert_eq!(restored.ctrl.n_groups(), ck.int_bits.len());
        assert_eq!(restored.ctrl.int_bits_vec(), ck.int_bits);
        assert!(!restored.half);
        // widths survive adoption: group 0 (l0.w) is an update-kind
        // group at bits_up, group 2 (l0.z) a computation group at
        // bits_comp
        assert_eq!(restored.ctrl.format(0).total_bits, 12);
        assert_eq!(restored.ctrl.format(2).total_bits, 10);
    }

    #[test]
    fn restore_keeps_float32_sentinel() {
        let ck = sample_checkpoint(Arithmetic::Float32);
        let restored = ck.restore().expect("restore");
        for g in 0..restored.ctrl.n_groups() {
            assert!(restored.ctrl.format(g).is_float32());
        }
    }

    #[test]
    fn version_gate_is_a_distinct_error() {
        let ck = sample_checkpoint(Arithmetic::Float32);
        let Json::Object(mut m) = ck.to_json() else { panic!("object") };
        m.insert("version".into(), Json::Num(99.0));
        let err = Checkpoint::from_json(&Json::Object(m)).unwrap_err();
        assert!(format!("{err:#}").contains("unsupported checkpoint version 99"), "{err:#}");
    }

    #[test]
    fn checksum_detects_tampering() {
        let ck = sample_checkpoint(Arithmetic::Float32);
        let Json::Object(mut m) = ck.to_json() else { panic!("object") };
        m.insert("seed".into(), Json::Num(8.0));
        let err = Checkpoint::from_json(&Json::Object(m)).unwrap_err();
        assert!(format!("{err:#}").contains("checksum mismatch"), "{err:#}");
    }

    #[test]
    fn corrupt_json_is_a_distinct_error() {
        let err = Checkpoint::parse("{ not json").unwrap_err();
        assert!(format!("{err:#}").contains("not valid JSON"), "{err:#}");
    }

    #[test]
    fn shape_mismatch_is_a_distinct_error() {
        let mut ck = sample_checkpoint(Arithmetic::Float32);
        // break the first hidden width: stored params no longer fit the
        // manifest the topology realizes to
        ck.topology.hidden[0] = 7;
        let err = ck.restore().unwrap_err();
        assert!(format!("{err:#}").contains("topology/dataset mismatch"), "{err:#}");
    }

    #[test]
    fn scale_table_length_mismatch_is_a_distinct_error() {
        let mut ck = sample_checkpoint(Arithmetic::Fixed { bits_comp: 10, bits_up: 12, int_bits: 1 });
        ck.int_bits.pop();
        let err = ck.restore().unwrap_err();
        assert!(format!("{err:#}").contains("scale table"), "{err:#}");
    }

    #[test]
    fn arithmetic_json_mirrors_experiment_config_keys() {
        let j = arithmetic_to_json(&Arithmetic::Dynamic {
            bits_comp: 10,
            bits_up: 12,
            max_overflow_rate: 0.01,
            update_every_examples: 100,
            init_int_bits: 1,
            warmup_steps: 10,
        });
        assert_eq!(j.get("kind").unwrap().as_str().unwrap(), "dynamic");
        assert_eq!(j.get("bits_comp").unwrap().as_i64().unwrap(), 10);
        assert_eq!(j.get("max_overflow_rate").unwrap().as_f64().unwrap(), 0.01);
        assert_eq!(j.get("warmup_steps").unwrap().as_usize().unwrap(), 10);
    }
}
