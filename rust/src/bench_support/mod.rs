//! Bench harness substrate (criterion is unavailable offline): timing,
//! robust summary statistics and paper-style ASCII tables/series.
//!
//! Every `benches/*.rs` binary is `harness = false` and uses this module
//! to print the rows/series the paper's tables and figures report.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use crate::config::Json;

/// Summary statistics over a sample of durations or values.
#[derive(Clone, Debug)]
pub struct Stats {
    pub n: usize,
    pub mean: f64,
    pub sd: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub max: f64,
}

impl Stats {
    pub fn from_values(values: &[f64]) -> Stats {
        assert!(!values.is_empty());
        let n = values.len();
        let mean = values.iter().sum::<f64>() / n as f64;
        let var = values.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / n as f64;
        let mut sorted = values.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |p: f64| sorted[((p * (n - 1) as f64).round() as usize).min(n - 1)];
        Stats {
            n,
            mean,
            sd: var.sqrt(),
            min: sorted[0],
            p50: pct(0.5),
            p90: pct(0.9),
            max: sorted[n - 1],
        }
    }

    pub fn from_durations(ds: &[Duration]) -> Stats {
        let vals: Vec<f64> = ds.iter().map(|d| d.as_secs_f64()).collect();
        Stats::from_values(&vals)
    }
}

/// Time `f` for `warmup + iters` runs; returns stats over the timed runs.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed());
    }
    Stats::from_durations(&times)
}

/// Scale factor for bench workloads: `LPDNN_BENCH_SCALE` (default 1.0).
/// Benches multiply their step counts/dataset sizes by this, so CI can run
/// `LPDNN_BENCH_SCALE=0.1 cargo bench` for a quick pass.
pub fn bench_scale() -> f64 {
    std::env::var("LPDNN_BENCH_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(1.0)
}

/// Apply the bench scale to a step/sample count (min 1).
pub fn scaled(n: usize) -> usize {
    ((n as f64 * bench_scale()).round() as usize).max(1)
}

/// Paper-style ASCII table printer.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn to_string(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let mut out = String::new();
        let sep = |out: &mut String| {
            for w in &widths {
                out.push('+');
                out.push_str(&"-".repeat(w + 2));
            }
            out.push_str("+\n");
        };
        sep(&mut out);
        for (w, h) in widths.iter().zip(&self.headers) {
            out.push_str(&format!("| {h:<w$} "));
        }
        out.push_str("|\n");
        sep(&mut out);
        for row in &self.rows {
            for (w, c) in widths.iter().zip(row) {
                out.push_str(&format!("| {c:<w$} "));
            }
            out.push_str("|\n");
        }
        sep(&mut out);
        out
    }

    pub fn print(&self) {
        print!("{}", self.to_string());
    }

    /// The table as a versioned JSON document so benches can persist
    /// their results (e.g. `BENCH_perf.json`) in a form CI and the
    /// EXPERIMENTS.md tooling can grep and diff across commits:
    /// `{"version": 1, "headers": [...], "rows": [{header: cell}, ...]}`.
    pub fn to_json(&self) -> Json {
        let rows: Vec<Json> = self
            .rows
            .iter()
            .map(|row| {
                let m: BTreeMap<String, Json> = self
                    .headers
                    .iter()
                    .zip(row)
                    .map(|(h, c)| (h.clone(), Json::Str(c.clone())))
                    .collect();
                Json::Object(m)
            })
            .collect();
        let mut doc = BTreeMap::new();
        doc.insert("version".to_string(), Json::Num(1.0));
        doc.insert(
            "headers".to_string(),
            Json::Array(self.headers.iter().map(|h| Json::Str(h.clone())).collect()),
        );
        doc.insert("rows".to_string(), Json::Array(rows));
        Json::Object(doc)
    }
}

/// An (x, y) series printer with a crude unicode bar chart — enough to see
/// the "cliff" shapes the paper's figures show in a terminal.
pub fn print_series(title: &str, xlabel: &str, points: &[(f64, f64)]) {
    println!("## {title}");
    let ymax = points.iter().map(|&(_, y)| y).fold(f64::NAN, f64::max).max(1e-9);
    for &(x, y) in points {
        let bar_len = ((y / ymax) * 40.0).round() as usize;
        println!("  {xlabel}={x:<8} {y:<10.4} {}", "#".repeat(bar_len.min(60)));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_of_known_sample() {
        let s = Stats::from_values(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
        assert!((s.sd - 2f64.sqrt()).abs() < 1e-9);
    }

    #[test]
    fn bench_runs_the_closure() {
        let mut count = 0;
        let s = bench(2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(s.n, 5);
        assert!(s.mean >= 0.0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["format", "error"]);
        t.row(&["float32".to_string(), "0.0105".to_string()]);
        t.row(&["dynamic(10/12)".to_string(), "0.0128".to_string()]);
        let s = t.to_string();
        assert!(s.contains("| format         |"));
        assert!(s.lines().count() >= 6);
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only one".to_string()]);
    }

    #[test]
    fn table_to_json_round_trips_through_the_parser() {
        let mut t = Table::new(&["benchmark", "result"]);
        t.row(&["int gemm nn".to_string(), "simulated 1.0ms | integer 0.5ms".to_string()]);
        let doc = crate::config::json::parse(&t.to_json().to_string_pretty()).expect("json");
        assert_eq!(doc.get("version").unwrap().as_usize().unwrap(), 1);
        assert_eq!(
            doc.get("headers").unwrap().as_str_vec().unwrap(),
            vec!["benchmark".to_string(), "result".to_string()]
        );
        let rows = doc.get("rows").unwrap().as_array().unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].get("benchmark").unwrap().as_str().unwrap(), "int gemm nn");
        assert!(rows[0].get("result").unwrap().as_str().unwrap().contains("integer"));
    }

    #[test]
    fn scaled_respects_min() {
        assert!(scaled(1) >= 1);
    }
}
