//! Serializable run reports: machine-readable sweep results.
//!
//! Every paper figure is a sweep, and downstream tooling (plotting,
//! regression tracking, CI smoke checks) wants the rows as data, not as
//! stderr lines. [`RunReport`] is the serializable subset of a
//! [`RunResult`]; [`SweepReport`] is a whole sweep — baseline plus rows
//! with the paper's normalized errors — writable as JSON through the
//! dependency-free writer in [`crate::config::json`] and parseable back
//! with the same module (`lpdnn sweep --report out.json` emits one).
//!
//! The schema is versioned (`"version": 1`) and keys serialize in
//! sorted order (the writer's `BTreeMap`), so emitted files are
//! diff-stable and golden-testable.

use std::collections::BTreeMap;
use std::path::Path;

use super::sweep::SweepOutcome;
use super::trainer::RunResult;
use crate::config::json::{Json, JsonError};
use crate::error::Context;

/// Schema version stamped into every [`SweepReport`].
pub const REPORT_VERSION: i64 = 1;

/// The serializable subset of one finished run.
#[derive(Clone, Debug, PartialEq)]
pub struct RunReport {
    pub name: String,
    pub label: String,
    pub backend: String,
    pub test_error: f64,
    /// Tail-averaged final training loss (NaN serializes as null).
    pub train_loss: f64,
    /// Per-group int_bits at the end (empty for non-dynamic runs is
    /// never the case — the controller always reports — but tolerated).
    pub final_int_bits: Vec<i32>,
    pub steps: usize,
    pub wallclock_secs: f64,
}

impl RunReport {
    pub fn from_result(r: &RunResult) -> RunReport {
        RunReport {
            name: r.config_name.clone(),
            label: r.label.clone(),
            backend: r.backend_name.clone(),
            test_error: r.test_error,
            train_loss: r.train_loss as f64,
            final_int_bits: r.final_int_bits.clone(),
            steps: r.steps_run,
            wallclock_secs: r.wallclock.as_secs_f64(),
        }
    }

    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("name".to_string(), Json::Str(self.name.clone()));
        m.insert("label".to_string(), Json::Str(self.label.clone()));
        m.insert("backend".to_string(), Json::Str(self.backend.clone()));
        m.insert("test_error".to_string(), Json::Num(self.test_error));
        m.insert("train_loss".to_string(), Json::Num(self.train_loss));
        m.insert(
            "final_int_bits".to_string(),
            Json::Array(self.final_int_bits.iter().map(|&b| Json::Num(b as f64)).collect()),
        );
        m.insert("steps".to_string(), Json::Num(self.steps as f64));
        m.insert("wallclock_secs".to_string(), Json::Num(self.wallclock_secs));
        Json::Object(m)
    }

    pub fn from_json(v: &Json) -> crate::Result<RunReport> {
        let bits = v
            .get("final_int_bits")?
            .as_array()?
            .iter()
            .map(|b| b.as_i64().map(|x| x as i32))
            .collect::<Result<Vec<i32>, JsonError>>()?;
        Ok(RunReport {
            name: v.get("name")?.as_str()?.to_string(),
            label: v.get("label")?.as_str()?.to_string(),
            backend: v.get("backend")?.as_str()?.to_string(),
            test_error: num_or_nan(v.get("test_error")?)?,
            train_loss: num_or_nan(v.get("train_loss")?)?,
            final_int_bits: bits,
            steps: v.get("steps")?.as_usize()?,
            wallclock_secs: num_or_nan(v.get("wallclock_secs")?)?,
        })
    }
}

/// One serialized sweep row: label, normalized error, full run report.
#[derive(Clone, Debug, PartialEq)]
pub struct SweepRowReport {
    pub label: String,
    /// test error / baseline test error (the paper's presentation).
    pub normalized: f64,
    pub run: RunReport,
}

impl SweepRowReport {
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("label".to_string(), Json::Str(self.label.clone()));
        m.insert("normalized".to_string(), Json::Num(self.normalized));
        m.insert("run".to_string(), self.run.to_json());
        Json::Object(m)
    }

    pub fn from_json(v: &Json) -> crate::Result<SweepRowReport> {
        Ok(SweepRowReport {
            label: v.get("label")?.as_str()?.to_string(),
            normalized: num_or_nan(v.get("normalized")?)?,
            run: RunReport::from_json(v.get("run")?)?,
        })
    }
}

/// A whole sweep, serializable: baseline + rows in point order.
#[derive(Clone, Debug, PartialEq)]
pub struct SweepReport {
    /// Backend the sweep ran on.
    pub backend: String,
    /// Worker-pool size the sweep was executed with (informational:
    /// rows are bit-identical for any value).
    pub jobs: usize,
    pub baseline: RunReport,
    pub rows: Vec<SweepRowReport>,
}

impl SweepReport {
    pub fn from_outcome(outcome: &SweepOutcome, jobs: usize) -> SweepReport {
        SweepReport {
            backend: outcome.baseline.backend_name.clone(),
            jobs,
            baseline: RunReport::from_result(&outcome.baseline),
            rows: outcome
                .rows
                .iter()
                .map(|r| SweepRowReport {
                    label: r.label.clone(),
                    normalized: r.normalized,
                    run: RunReport::from_result(&r.result),
                })
                .collect(),
        }
    }

    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("version".to_string(), Json::Num(REPORT_VERSION as f64));
        m.insert("backend".to_string(), Json::Str(self.backend.clone()));
        m.insert("jobs".to_string(), Json::Num(self.jobs as f64));
        m.insert("baseline".to_string(), self.baseline.to_json());
        m.insert(
            "rows".to_string(),
            Json::Array(self.rows.iter().map(|r| r.to_json()).collect()),
        );
        Json::Object(m)
    }

    pub fn from_json(v: &Json) -> crate::Result<SweepReport> {
        if let Some(ver) = v.opt("version") {
            let ver = ver.as_i64()?;
            crate::ensure!(
                ver == REPORT_VERSION,
                "unsupported sweep report version {ver} (this build reads {REPORT_VERSION})"
            );
        }
        Ok(SweepReport {
            backend: v.get("backend")?.as_str()?.to_string(),
            jobs: v.get("jobs")?.as_usize()?,
            baseline: RunReport::from_json(v.get("baseline")?)?,
            rows: v
                .get("rows")?
                .as_array()?
                .iter()
                .map(SweepRowReport::from_json)
                .collect::<crate::Result<Vec<_>>>()?,
        })
    }

    /// Pretty-printed JSON document (trailing newline included).
    pub fn to_json_string(&self) -> String {
        let mut s = self.to_json().to_string_pretty();
        s.push('\n');
        s
    }

    /// Write the report to `path`.
    pub fn write(&self, path: impl AsRef<Path>) -> crate::Result<()> {
        let path = path.as_ref();
        std::fs::write(path, self.to_json_string())
            .with_context(|| format!("writing sweep report {path:?}"))
    }
}

/// JSON numbers, tolerating the writer's NaN→null convention.
fn num_or_nan(v: &Json) -> crate::Result<f64> {
    match v {
        Json::Null => Ok(f64::NAN),
        other => Ok(other.as_f64()?),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::json;

    fn sample() -> SweepReport {
        SweepReport {
            backend: "native".into(),
            jobs: 4,
            baseline: RunReport {
                name: "base".into(),
                label: "base".into(),
                backend: "native".into(),
                test_error: 0.25,
                train_loss: 0.5,
                final_int_bits: vec![3, -1],
                steps: 10,
                wallclock_secs: 0.75,
            },
            rows: vec![SweepRowReport {
                label: "p".into(),
                normalized: 1.5,
                run: RunReport {
                    name: "point".into(),
                    label: "p".into(),
                    backend: "native".into(),
                    test_error: 0.375,
                    train_loss: 0.25,
                    final_int_bits: vec![],
                    steps: 10,
                    wallclock_secs: 1.25,
                },
            }],
        }
    }

    #[test]
    fn report_roundtrips_through_the_json_module() {
        let report = sample();
        let text = report.to_json_string();
        let parsed = json::parse(&text).unwrap();
        assert_eq!(SweepReport::from_json(&parsed).unwrap(), report);
        // compact form too
        let compact = json::parse(&report.to_json().to_string()).unwrap();
        assert_eq!(SweepReport::from_json(&compact).unwrap(), report);
    }

    #[test]
    fn nan_losses_serialize_as_null_and_read_back_as_nan() {
        let mut report = sample();
        report.baseline.train_loss = f64::NAN;
        let parsed = json::parse(&report.to_json().to_string()).unwrap();
        let back = SweepReport::from_json(&parsed).unwrap();
        assert!(back.baseline.train_loss.is_nan());
    }

    #[test]
    fn version_mismatch_is_rejected() {
        let mut doc = sample().to_json();
        if let Json::Object(m) = &mut doc {
            m.insert("version".to_string(), Json::Num(99.0));
        }
        let err = SweepReport::from_json(&doc).unwrap_err();
        assert!(format!("{err}").contains("version 99"));
    }

    #[test]
    fn from_result_carries_the_run_fields() {
        let r = RunResult {
            config_name: "cfg".into(),
            label: "lbl".into(),
            backend_name: "native".into(),
            test_error: 0.125,
            train_loss: 0.5,
            metrics: Default::default(),
            final_int_bits: vec![2],
            steps_run: 7,
            wallclock: std::time::Duration::from_millis(250),
        };
        let rep = RunReport::from_result(&r);
        assert_eq!(rep.name, "cfg");
        assert_eq!(rep.label, "lbl");
        assert_eq!(rep.steps, 7);
        assert_eq!(rep.wallclock_secs, 0.25);
        assert_eq!(rep.final_int_bits, vec![2]);
    }
}
