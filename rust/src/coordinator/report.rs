//! Serializable run reports: machine-readable sweep results.
//!
//! Every paper figure is a sweep, and downstream tooling (plotting,
//! regression tracking, CI smoke checks) wants the rows as data, not as
//! stderr lines. [`RunReport`] is the serializable subset of a
//! [`RunResult`]; [`SweepReport`] is a whole sweep — baseline plus rows
//! with the paper's normalized errors — writable as JSON through the
//! dependency-free writer in [`crate::config::json`] and parseable back
//! with the same module (`lpdnn sweep --report out.json` emits one).
//!
//! The schema is versioned (`"version": 1`) and keys serialize in
//! sorted order (the writer's `BTreeMap`), so emitted files are
//! diff-stable and golden-testable.

use std::collections::BTreeMap;
use std::path::Path;

use super::sweep::SweepOutcome;
use super::trainer::RunResult;
use crate::config::json::{Json, JsonError};
use crate::error::Context;
use crate::tensor::ops::GemmSiteCounts;

/// Schema version stamped into every [`SweepReport`].
pub const REPORT_VERSION: i64 = 1;

/// The serializable subset of one finished run.
#[derive(Clone, Debug, PartialEq)]
pub struct RunReport {
    pub name: String,
    pub label: String,
    pub backend: String,
    pub test_error: f64,
    /// Tail-averaged final training loss (NaN serializes as null).
    pub train_loss: f64,
    /// Per-group int_bits at the end (empty for non-dynamic runs is
    /// never the case — the controller always reports — but tolerated).
    pub final_int_bits: Vec<i32>,
    pub steps: usize,
    pub wallclock_secs: f64,
    /// Per-site GEMM lowering-outcome counters (`"<layer>.<site>"`
    /// keys). Omitted from the JSON when empty, so reports from
    /// backends without a layer graph — and golden files predating the
    /// section — stay byte-identical.
    pub int_gemm_sites: BTreeMap<String, GemmSiteCounts>,
}

impl RunReport {
    pub fn from_result(r: &RunResult) -> RunReport {
        RunReport {
            name: r.config_name.clone(),
            label: r.label.clone(),
            backend: r.backend_name.clone(),
            test_error: r.test_error,
            train_loss: r.train_loss as f64,
            final_int_bits: r.final_int_bits.clone(),
            steps: r.steps_run,
            wallclock_secs: r.wallclock.as_secs_f64(),
            int_gemm_sites: r.int_gemm_sites.clone(),
        }
    }

    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("name".to_string(), Json::Str(self.name.clone()));
        m.insert("label".to_string(), Json::Str(self.label.clone()));
        m.insert("backend".to_string(), Json::Str(self.backend.clone()));
        m.insert("test_error".to_string(), Json::Num(self.test_error));
        m.insert("train_loss".to_string(), Json::Num(self.train_loss));
        m.insert(
            "final_int_bits".to_string(),
            Json::Array(self.final_int_bits.iter().map(|&b| Json::Num(b as f64)).collect()),
        );
        m.insert("steps".to_string(), Json::Num(self.steps as f64));
        m.insert("wallclock_secs".to_string(), Json::Num(self.wallclock_secs));
        if !self.int_gemm_sites.is_empty() {
            let sites = self
                .int_gemm_sites
                .iter()
                .map(|(k, c)| (k.clone(), counts_to_json(c)))
                .collect::<BTreeMap<_, _>>();
            m.insert("int_gemm_sites".to_string(), Json::Object(sites));
        }
        Json::Object(m)
    }

    pub fn from_json(v: &Json) -> crate::Result<RunReport> {
        let bits = v
            .get("final_int_bits")?
            .as_array()?
            .iter()
            .map(|b| b.as_i64().map(|x| x as i32))
            .collect::<Result<Vec<i32>, JsonError>>()?;
        let mut sites = BTreeMap::new();
        if let Some(sv) = v.opt("int_gemm_sites") {
            for (k, c) in sv.as_object()? {
                sites.insert(k.clone(), counts_from_json(c)?);
            }
        }
        Ok(RunReport {
            name: v.get("name")?.as_str()?.to_string(),
            label: v.get("label")?.as_str()?.to_string(),
            backend: v.get("backend")?.as_str()?.to_string(),
            test_error: num_or_nan(v.get("test_error")?)?,
            train_loss: num_or_nan(v.get("train_loss")?)?,
            final_int_bits: bits,
            steps: v.get("steps")?.as_usize()?,
            wallclock_secs: num_or_nan(v.get("wallclock_secs")?)?,
            int_gemm_sites: sites,
        })
    }
}

/// One site's lowering counters as a JSON object. `simulated` is the
/// derived rejection total (the headline number a smoke check greps);
/// the five reason counters are the breakdown.
fn counts_to_json(c: &GemmSiteCounts) -> Json {
    let mut m = BTreeMap::new();
    m.insert("int".to_string(), Json::Num(c.int as f64));
    m.insert("split".to_string(), Json::Num(c.split as f64));
    m.insert("simulated".to_string(), Json::Num(c.simulated() as f64));
    m.insert("disabled".to_string(), Json::Num(c.disabled as f64));
    m.insert("dirty_dst".to_string(), Json::Num(c.dirty_dst as f64));
    m.insert("unpackable".to_string(), Json::Num(c.unpackable as f64));
    m.insert("exp_window".to_string(), Json::Num(c.exp_window as f64));
    m.insert("acc_bound".to_string(), Json::Num(c.acc_bound as f64));
    Json::Object(m)
}

/// Inverse of [`counts_to_json`]; the derived `simulated` field is
/// recomputed, not read.
fn counts_from_json(v: &Json) -> crate::Result<GemmSiteCounts> {
    let field = |k: &str| -> crate::Result<u64> { Ok(v.get(k)?.as_i64()? as u64) };
    Ok(GemmSiteCounts {
        int: field("int")?,
        split: field("split")?,
        disabled: field("disabled")?,
        dirty_dst: field("dirty_dst")?,
        unpackable: field("unpackable")?,
        exp_window: field("exp_window")?,
        acc_bound: field("acc_bound")?,
    })
}

/// One serialized sweep row: label, normalized error, full run report.
#[derive(Clone, Debug, PartialEq)]
pub struct SweepRowReport {
    pub label: String,
    /// test error / baseline test error (the paper's presentation).
    pub normalized: f64,
    pub run: RunReport,
}

impl SweepRowReport {
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("label".to_string(), Json::Str(self.label.clone()));
        m.insert("normalized".to_string(), Json::Num(self.normalized));
        m.insert("run".to_string(), self.run.to_json());
        Json::Object(m)
    }

    pub fn from_json(v: &Json) -> crate::Result<SweepRowReport> {
        Ok(SweepRowReport {
            label: v.get("label")?.as_str()?.to_string(),
            normalized: num_or_nan(v.get("normalized")?)?,
            run: RunReport::from_json(v.get("run")?)?,
        })
    }
}

/// A whole sweep, serializable: baseline + rows in point order.
#[derive(Clone, Debug, PartialEq)]
pub struct SweepReport {
    /// Backend the sweep ran on.
    pub backend: String,
    /// Worker-pool size the sweep was executed with (informational:
    /// rows are bit-identical for any value).
    pub jobs: usize,
    pub baseline: RunReport,
    pub rows: Vec<SweepRowReport>,
}

impl SweepReport {
    pub fn from_outcome(outcome: &SweepOutcome, jobs: usize) -> SweepReport {
        SweepReport {
            backend: outcome.baseline.backend_name.clone(),
            jobs,
            baseline: RunReport::from_result(&outcome.baseline),
            rows: outcome
                .rows
                .iter()
                .map(|r| SweepRowReport {
                    label: r.label.clone(),
                    normalized: r.normalized,
                    run: RunReport::from_result(&r.result),
                })
                .collect(),
        }
    }

    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("version".to_string(), Json::Num(REPORT_VERSION as f64));
        m.insert("backend".to_string(), Json::Str(self.backend.clone()));
        m.insert("jobs".to_string(), Json::Num(self.jobs as f64));
        m.insert("baseline".to_string(), self.baseline.to_json());
        m.insert(
            "rows".to_string(),
            Json::Array(self.rows.iter().map(|r| r.to_json()).collect()),
        );
        Json::Object(m)
    }

    pub fn from_json(v: &Json) -> crate::Result<SweepReport> {
        if let Some(ver) = v.opt("version") {
            let ver = ver.as_i64()?;
            crate::ensure!(
                ver == REPORT_VERSION,
                "unsupported sweep report version {ver} (this build reads {REPORT_VERSION})"
            );
        }
        Ok(SweepReport {
            backend: v.get("backend")?.as_str()?.to_string(),
            jobs: v.get("jobs")?.as_usize()?,
            baseline: RunReport::from_json(v.get("baseline")?)?,
            rows: v
                .get("rows")?
                .as_array()?
                .iter()
                .map(SweepRowReport::from_json)
                .collect::<crate::Result<Vec<_>>>()?,
        })
    }

    /// Pretty-printed JSON document (trailing newline included).
    pub fn to_json_string(&self) -> String {
        let mut s = self.to_json().to_string_pretty();
        s.push('\n');
        s
    }

    /// Write the report to `path`.
    pub fn write(&self, path: impl AsRef<Path>) -> crate::Result<()> {
        let path = path.as_ref();
        std::fs::write(path, self.to_json_string())
            .with_context(|| format!("writing sweep report {path:?}"))
    }
}

/// JSON numbers, tolerating the writer's NaN→null convention.
fn num_or_nan(v: &Json) -> crate::Result<f64> {
    match v {
        Json::Null => Ok(f64::NAN),
        other => Ok(other.as_f64()?),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::json;

    fn sample() -> SweepReport {
        SweepReport {
            backend: "native".into(),
            jobs: 4,
            baseline: RunReport {
                name: "base".into(),
                label: "base".into(),
                backend: "native".into(),
                test_error: 0.25,
                train_loss: 0.5,
                final_int_bits: vec![3, -1],
                steps: 10,
                wallclock_secs: 0.75,
                int_gemm_sites: BTreeMap::new(),
            },
            rows: vec![SweepRowReport {
                label: "p".into(),
                normalized: 1.5,
                run: RunReport {
                    name: "point".into(),
                    label: "p".into(),
                    backend: "native".into(),
                    test_error: 0.375,
                    train_loss: 0.25,
                    final_int_bits: vec![],
                    steps: 10,
                    wallclock_secs: 1.25,
                    int_gemm_sites: BTreeMap::new(),
                },
            }],
        }
    }

    #[test]
    fn report_roundtrips_through_the_json_module() {
        let report = sample();
        let text = report.to_json_string();
        let parsed = json::parse(&text).unwrap();
        assert_eq!(SweepReport::from_json(&parsed).unwrap(), report);
        // compact form too
        let compact = json::parse(&report.to_json().to_string()).unwrap();
        assert_eq!(SweepReport::from_json(&compact).unwrap(), report);
    }

    #[test]
    fn nan_losses_serialize_as_null_and_read_back_as_nan() {
        let mut report = sample();
        report.baseline.train_loss = f64::NAN;
        let parsed = json::parse(&report.to_json().to_string()).unwrap();
        let back = SweepReport::from_json(&parsed).unwrap();
        assert!(back.baseline.train_loss.is_nan());
    }

    #[test]
    fn version_mismatch_is_rejected() {
        let mut doc = sample().to_json();
        if let Json::Object(m) = &mut doc {
            m.insert("version".to_string(), Json::Num(99.0));
        }
        let err = SweepReport::from_json(&doc).unwrap_err();
        assert!(format!("{err}").contains("version 99"));
    }

    #[test]
    fn from_result_carries_the_run_fields() {
        let r = RunResult {
            config_name: "cfg".into(),
            label: "lbl".into(),
            backend_name: "native".into(),
            test_error: 0.125,
            train_loss: 0.5,
            metrics: Default::default(),
            final_int_bits: vec![2],
            steps_run: 7,
            wallclock: std::time::Duration::from_millis(250),
            int_gemm_sites: BTreeMap::from([(
                "softmax(4)@l3.z".to_string(),
                GemmSiteCounts { int: 7, ..Default::default() },
            )]),
        };
        let rep = RunReport::from_result(&r);
        assert_eq!(rep.name, "cfg");
        assert_eq!(rep.label, "lbl");
        assert_eq!(rep.steps, 7);
        assert_eq!(rep.wallclock_secs, 0.25);
        assert_eq!(rep.final_int_bits, vec![2]);
        assert_eq!(rep.int_gemm_sites["softmax(4)@l3.z"].int, 7);
    }

    #[test]
    fn int_gemm_sites_roundtrip_and_empty_section_is_omitted() {
        // empty map: key absent from the JSON (old golden files parse)
        let empty = sample();
        assert!(!empty.to_json_string().contains("int_gemm_sites"));

        let mut report = sample();
        report.baseline.int_gemm_sites.insert(
            "maxout(8x2)@l0.z".to_string(),
            GemmSiteCounts { int: 5, split: 3, acc_bound: 1, ..Default::default() },
        );
        let text = report.to_json_string();
        assert!(text.contains("int_gemm_sites") && text.contains("\"split\": 3"));
        // the derived rejection total serializes alongside the breakdown
        assert!(text.contains("\"simulated\": 1"));
        let parsed = json::parse(&text).unwrap();
        assert_eq!(SweepReport::from_json(&parsed).unwrap(), report);
    }
}
