//! L3 coordinator: the training orchestrator + the paper's dynamic fixed
//! point scale controller.
//!
//! * [`trainer`]    — one experiment end to end (init, loop, schedules,
//!   eval); feeds any [`crate::runtime::Backend`]'s train step and
//!   consumes its overflow counters.
//! * [`scale_ctrl`] — per-group scaling-factor state + the section 5
//!   update rule. The *only* stateful online mechanism in the paper, and
//!   the part that genuinely belongs in the coordinator.
//! * [`metrics`]    — loss/error/scale time series, CSV/JSON export.
//! * [`sweep`]      — figure-regeneration machinery (normalized errors).

pub mod metrics;
pub mod scale_ctrl;
pub mod sweep;
pub mod trainer;

pub use metrics::Metrics;
pub use scale_ctrl::ScaleController;
pub use sweep::{run_sweep, SweepPoint, SweepRow};
pub use trainer::{RunResult, Trainer};
