//! L3 coordinator: the experiment session API + the paper's dynamic
//! fixed point scale controller.
//!
//! * [`session`]    — [`Session`], the entry point: owns backend
//!   construction (via [`crate::runtime::BackendSpec`]), runs single
//!   experiments and whole sweeps through a worker pool (`jobs` knob),
//!   and fans progress out to the attached observers.
//! * [`observer`]   — [`RunObserver`], the typed event stream every run
//!   emits (`on_step` / `on_eval` / `on_scale_move` / `on_run_end`);
//!   the stderr progress printer and the `--loss-csv` writer are
//!   implementations.
//! * [`report`]     — serializable [`RunReport`]/[`SweepReport`]
//!   (dependency-free JSON via [`crate::config::json`]).
//! * [`scale_ctrl`] — per-group scaling-factor state + the section 5
//!   update rule. The *only* stateful online mechanism in the paper, and
//!   the part that genuinely belongs in the coordinator.
//! * [`metrics`]    — loss/error/scale time series, CSV/JSON export.
//! * [`sweep`]      — sweep data model (points, rows, normalized
//!   errors — the figure-regeneration machinery).
//!
//! The training loop itself (`trainer`, crate-internal) feeds any
//! [`crate::runtime::Backend`]'s train step and consumes its overflow
//! counters; its RNG stream constants ([`RNG_FORK_INIT`],
//! [`RNG_FORK_BATCHER`], [`WARMUP_SEED_XOR`]) are re-exported here.

pub mod metrics;
pub mod observer;
pub mod report;
pub mod scale_ctrl;
pub mod session;
pub mod sweep;
mod trainer;

pub use metrics::Metrics;
pub use observer::{
    LossCsvObserver, ObserverEvent, Observers, RecordingObserver, RunMeta, RunObserver,
    RunRole, StderrProgress,
};
pub use report::{RunReport, SweepReport, SweepRowReport, REPORT_VERSION};
pub use scale_ctrl::ScaleController;
pub use session::{oversubscription_warning, Session};
pub use sweep::{SweepOutcome, SweepPoint, SweepRow};
pub use trainer::{RunResult, RNG_FORK_BATCHER, RNG_FORK_INIT, WARMUP_SEED_XOR};
