//! The dynamic fixed point scale controller — the paper's section 5
//! mechanism, owned by L3.
//!
//! One [`GroupState`](crate::arith::GroupState) per scaling-factor group
//! (8 kinds × layers, see `runtime::manifest`). Every train step the
//! compiled artifact returns the `[n_groups, 3]` overflow-counter matrix;
//! the controller accumulates it and, every `update_every_examples`
//! examples (paper: 10 000), applies the ×2/÷2 rule per group.
//!
//! The same type serves the static arithmetics: for float32/float16 the
//! step vector is all zeros (passthrough sentinel), for fixed point all
//! groups share one frozen format — `after_batch` simply never updates.

use crate::arith::{FixedFormat, GroupState, OverflowCounts, UpdateDecision};
use crate::runtime::manifest::{N_KINDS, UPDATE_KINDS};
use crate::tensor::Tensor;

/// Per-group scale management for one training run.
#[derive(Clone, Debug)]
pub struct ScaleController {
    groups: Vec<GroupState>,
    dynamic: bool,
    max_rate: f64,
    update_every_examples: usize,
    examples_since_update: usize,
    /// (step_index, group, new int_bits) log of every scale move.
    pub decisions_log: Vec<(usize, usize, i32)>,
}

impl ScaleController {
    /// Static controller: every group frozen at its kind's format.
    /// `comp_fmt` applies to signal kinds, `up_fmt` to parameter storage
    /// (paper section 6's two bit-widths).
    pub fn fixed(n_layers: usize, comp_fmt: FixedFormat, up_fmt: FixedFormat) -> Self {
        Self::build(n_layers, comp_fmt, up_fmt, false, 0.0, usize::MAX)
    }

    /// Dynamic controller (paper section 5).
    pub fn dynamic(
        n_layers: usize,
        comp_fmt: FixedFormat,
        up_fmt: FixedFormat,
        max_rate: f64,
        update_every_examples: usize,
    ) -> Self {
        Self::build(n_layers, comp_fmt, up_fmt, true, max_rate, update_every_examples)
    }

    fn build(
        n_layers: usize,
        comp_fmt: FixedFormat,
        up_fmt: FixedFormat,
        dynamic: bool,
        max_rate: f64,
        update_every_examples: usize,
    ) -> Self {
        let mut groups = Vec::with_capacity(n_layers * N_KINDS);
        for _layer in 0..n_layers {
            for kind in 0..N_KINDS {
                let fmt = if UPDATE_KINDS.contains(&kind) { up_fmt } else { comp_fmt };
                groups.push(GroupState::new(fmt));
            }
        }
        ScaleController {
            groups,
            dynamic,
            max_rate,
            update_every_examples,
            examples_since_update: 0,
            decisions_log: Vec::new(),
        }
    }

    pub fn n_groups(&self) -> usize {
        self.groups.len()
    }

    pub fn is_dynamic(&self) -> bool {
        self.dynamic
    }

    /// Current format of group `g`.
    pub fn format(&self, g: usize) -> FixedFormat {
        self.groups[g].fmt
    }

    /// Runtime `steps[n_groups]` vector for the compiled artifact.
    pub fn steps_vec(&self) -> Vec<f32> {
        self.groups.iter().map(|g| g.fmt.step()).collect()
    }

    /// Runtime `maxvs[n_groups]` vector for the compiled artifact.
    pub fn maxvs_vec(&self) -> Vec<f32> {
        self.groups.iter().map(|g| g.fmt.maxv()).collect()
    }

    /// Current int_bits per group (for logging / warmup transfer).
    pub fn int_bits_vec(&self) -> Vec<i32> {
        self.groups.iter().map(|g| g.fmt.int_bits).collect()
    }

    /// Adopt per-group int_bits (e.g. learned during high-precision
    /// warmup — paper 9.3) while keeping each group's bit-width.
    pub fn adopt_int_bits(&mut self, int_bits: &[i32]) {
        assert_eq!(int_bits.len(), self.groups.len());
        for (g, &ib) in self.groups.iter_mut().zip(int_bits) {
            g.fmt = FixedFormat::new(g.fmt.total_bits, ib);
        }
    }

    /// Feed one step's `[n_groups, 3]` overflow matrix from the artifact.
    pub fn observe_matrix(&mut self, overflow: &Tensor) {
        assert_eq!(overflow.shape(), &[self.groups.len(), 3], "overflow matrix shape");
        let d = overflow.data();
        for (i, g) in self.groups.iter_mut().enumerate() {
            g.observe(OverflowCounts {
                n_over: d[i * 3] as u64,
                n_half: d[i * 3 + 1] as u64,
                n_total: d[i * 3 + 2] as u64,
            });
        }
    }

    /// Advance the example counter; when the update interval elapses (and
    /// the controller is dynamic), apply the paper's rule to every group.
    /// Returns the number of scale moves made, if an update ran.
    pub fn after_batch(&mut self, batch_examples: usize, step_index: usize) -> Option<usize> {
        self.examples_since_update += batch_examples;
        if !self.dynamic || self.examples_since_update < self.update_every_examples {
            return None;
        }
        self.examples_since_update = 0;
        let mut moves = 0;
        for (gi, g) in self.groups.iter_mut().enumerate() {
            match g.maybe_update(self.max_rate) {
                UpdateDecision::Hold => {}
                _ => {
                    moves += 1;
                    self.decisions_log.push((step_index, gi, g.fmt.int_bits));
                }
            }
        }
        Some(moves)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn overflow(n_groups: usize, over: f32, half: f32, total: f32) -> Tensor {
        let mut d = Vec::with_capacity(n_groups * 3);
        for _ in 0..n_groups {
            d.extend_from_slice(&[over, half, total]);
        }
        Tensor::from_vec(&[n_groups, 3], d)
    }

    #[test]
    fn static_controller_never_moves() {
        let mut c = ScaleController::fixed(3, FixedFormat::new(20, 5), FixedFormat::new(20, 5));
        assert!(!c.is_dynamic());
        c.observe_matrix(&overflow(24, 1000.0, 1000.0, 1000.0));
        assert_eq!(c.after_batch(1_000_000, 0), None);
        assert!(c.steps_vec().iter().all(|&s| s == FixedFormat::new(20, 5).step()));
    }

    #[test]
    fn float32_controller_is_passthrough() {
        let c = ScaleController::fixed(2, FixedFormat::FLOAT32, FixedFormat::FLOAT32);
        assert!(c.steps_vec().iter().all(|&s| s == 0.0));
        assert!(c.maxvs_vec().iter().all(|&m| m == 0.0));
    }

    #[test]
    fn update_kinds_get_up_format() {
        let c = ScaleController::fixed(1, FixedFormat::new(10, 3), FixedFormat::new(12, 0));
        // kind order: w b z h dw db dz dh
        assert_eq!(c.format(0).total_bits, 12); // w
        assert_eq!(c.format(1).total_bits, 12); // b
        assert_eq!(c.format(2).total_bits, 10); // z
        assert_eq!(c.format(7).total_bits, 10); // dh
        assert_eq!(c.format(0).int_bits, 0);
        assert_eq!(c.format(2).int_bits, 3);
    }

    #[test]
    fn dynamic_controller_updates_on_interval() {
        let mut c = ScaleController::dynamic(
            1,
            FixedFormat::new(10, 2),
            FixedFormat::new(12, 2),
            1e-4,
            100, // examples
        );
        // overflowing every group
        c.observe_matrix(&overflow(8, 50.0, 60.0, 100.0));
        assert_eq!(c.after_batch(64, 0), None); // 64 < 100 examples
        c.observe_matrix(&overflow(8, 50.0, 60.0, 100.0));
        let moves = c.after_batch(64, 1).expect("tick after 128 examples");
        assert_eq!(moves, 8); // every group scaled up
        assert!(c.int_bits_vec().iter().all(|&b| b == 3));
        assert_eq!(c.decisions_log.len(), 8);
    }

    #[test]
    fn quiet_groups_gain_precision() {
        let mut c = ScaleController::dynamic(
            1,
            FixedFormat::new(10, 2),
            FixedFormat::new(12, 2),
            1e-4,
            10,
        );
        c.observe_matrix(&overflow(8, 0.0, 0.0, 10_000.0));
        c.after_batch(10, 0).unwrap();
        assert!(c.int_bits_vec().iter().all(|&b| b == 1));
    }

    #[test]
    fn adopt_int_bits_transfers_warmup_scales() {
        let mut c =
            ScaleController::dynamic(1, FixedFormat::new(10, 0), FixedFormat::new(12, 0), 1e-4, 10);
        c.adopt_int_bits(&[5, 4, 3, 2, 1, 0, -1, -2]);
        assert_eq!(c.int_bits_vec(), vec![5, 4, 3, 2, 1, 0, -1, -2]);
        // widths preserved
        assert_eq!(c.format(0).total_bits, 12);
        assert_eq!(c.format(2).total_bits, 10);
    }

    #[test]
    #[should_panic(expected = "overflow matrix shape")]
    fn shape_mismatch_panics() {
        let mut c = ScaleController::fixed(2, FixedFormat::FLOAT32, FixedFormat::FLOAT32);
        c.observe_matrix(&Tensor::zeros(&[3, 3]));
    }
}
