//! The dynamic fixed point scale controller — the paper's section 5
//! mechanism, owned by L3.
//!
//! One [`GroupState`](crate::arith::GroupState) per scaling-factor group
//! in the layer-major table (8 kinds per compute layer, see
//! `runtime::manifest`). The group **count comes from the model graph**
//! — [`Network::n_groups`](crate::golden::Network::n_groups) /
//! `ModelInfo::n_groups` — so deeper topologies get more controller rows
//! without any code change here. Every train step the backend returns
//! the `[n_groups, 3]` overflow-counter matrix; the controller
//! accumulates it and, every `update_every_examples` examples (paper:
//! 10 000), applies the ×2/÷2 rule per group.
//!
//! The same type serves the static arithmetics: for float32/float16 the
//! step vector is all zeros (passthrough sentinel), for fixed point all
//! groups share one frozen format — `after_batch` simply never updates.

use crate::arith::{FixedFormat, GroupState, OverflowCounts, UpdateDecision};
use crate::runtime::manifest::{N_KINDS, UPDATE_KINDS};
use crate::tensor::Tensor;

/// Per-group scale management for one training run.
#[derive(Clone, Debug)]
pub struct ScaleController {
    groups: Vec<GroupState>,
    dynamic: bool,
    max_rate: f64,
    update_every_examples: usize,
    examples_since_update: usize,
    /// (step_index, group, new int_bits) log of every scale move.
    pub decisions_log: Vec<(usize, usize, i32)>,
}

impl ScaleController {
    /// Static controller: every group frozen at its kind's format.
    /// `n_groups` is the graph-derived group count
    /// ([`Network::n_groups`](crate::golden::Network::n_groups));
    /// `comp_fmt` applies to signal kinds, `up_fmt` to parameter storage
    /// (paper section 6's two bit-widths).
    pub fn fixed(n_groups: usize, comp_fmt: FixedFormat, up_fmt: FixedFormat) -> Self {
        Self::build(n_groups, comp_fmt, up_fmt, false, 0.0, usize::MAX)
    }

    /// Dynamic controller (paper section 5). `n_groups` as in
    /// [`ScaleController::fixed`].
    pub fn dynamic(
        n_groups: usize,
        comp_fmt: FixedFormat,
        up_fmt: FixedFormat,
        max_rate: f64,
        update_every_examples: usize,
    ) -> Self {
        Self::build(n_groups, comp_fmt, up_fmt, true, max_rate, update_every_examples)
    }

    fn build(
        n_groups: usize,
        comp_fmt: FixedFormat,
        up_fmt: FixedFormat,
        dynamic: bool,
        max_rate: f64,
        update_every_examples: usize,
    ) -> Self {
        assert!(n_groups > 0, "controller needs at least one group");
        let mut groups = Vec::with_capacity(n_groups);
        for g in 0..n_groups {
            // layer-major table: the kind cycles within each layer row
            let kind = g % N_KINDS;
            let fmt = if UPDATE_KINDS.contains(&kind) { up_fmt } else { comp_fmt };
            groups.push(GroupState::new(fmt));
        }
        ScaleController {
            groups,
            dynamic,
            max_rate,
            update_every_examples,
            examples_since_update: 0,
            decisions_log: Vec::new(),
        }
    }

    pub fn n_groups(&self) -> usize {
        self.groups.len()
    }

    pub fn is_dynamic(&self) -> bool {
        self.dynamic
    }

    /// Current format of group `g`.
    pub fn format(&self, g: usize) -> FixedFormat {
        self.groups[g].fmt
    }

    /// Runtime `steps[n_groups]` vector for the compiled artifact.
    pub fn steps_vec(&self) -> Vec<f32> {
        self.groups.iter().map(|g| g.fmt.step()).collect()
    }

    /// Runtime `maxvs[n_groups]` vector for the compiled artifact.
    pub fn maxvs_vec(&self) -> Vec<f32> {
        self.groups.iter().map(|g| g.fmt.maxv()).collect()
    }

    /// Current int_bits per group (for logging / warmup transfer).
    pub fn int_bits_vec(&self) -> Vec<i32> {
        self.groups.iter().map(|g| g.fmt.int_bits).collect()
    }

    /// Adopt per-group int_bits (e.g. learned during high-precision
    /// warmup — paper 9.3) while keeping each group's bit-width.
    pub fn adopt_int_bits(&mut self, int_bits: &[i32]) {
        assert_eq!(int_bits.len(), self.groups.len());
        for (g, &ib) in self.groups.iter_mut().zip(int_bits) {
            g.fmt = FixedFormat::new(g.fmt.total_bits, ib);
        }
    }

    /// Feed one step's `[n_groups, 3]` overflow matrix from the artifact.
    pub fn observe_matrix(&mut self, overflow: &Tensor) {
        assert_eq!(overflow.shape(), &[self.groups.len(), 3], "overflow matrix shape");
        let d = overflow.data();
        for (i, g) in self.groups.iter_mut().enumerate() {
            g.observe(OverflowCounts {
                n_over: d[i * 3] as u64,
                n_half: d[i * 3 + 1] as u64,
                n_total: d[i * 3 + 2] as u64,
            });
        }
    }

    /// Advance the example counter; when the update interval elapses (and
    /// the controller is dynamic), apply the paper's rule to every group.
    /// Returns the number of scale moves made, if an update ran.
    pub fn after_batch(&mut self, batch_examples: usize, step_index: usize) -> Option<usize> {
        self.examples_since_update += batch_examples;
        if !self.dynamic || self.examples_since_update < self.update_every_examples {
            return None;
        }
        self.examples_since_update = 0;
        let mut moves = 0;
        for (gi, g) in self.groups.iter_mut().enumerate() {
            match g.maybe_update(self.max_rate) {
                UpdateDecision::Hold => {}
                _ => {
                    moves += 1;
                    self.decisions_log.push((step_index, gi, g.fmt.int_bits));
                }
            }
        }
        Some(moves)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn overflow(n_groups: usize, over: f32, half: f32, total: f32) -> Tensor {
        let mut d = Vec::with_capacity(n_groups * 3);
        for _ in 0..n_groups {
            d.extend_from_slice(&[over, half, total]);
        }
        Tensor::from_vec(&[n_groups, 3], d)
    }

    #[test]
    fn static_controller_never_moves() {
        let mut c = ScaleController::fixed(24, FixedFormat::new(20, 5), FixedFormat::new(20, 5));
        assert!(!c.is_dynamic());
        c.observe_matrix(&overflow(24, 1000.0, 1000.0, 1000.0));
        assert_eq!(c.after_batch(1_000_000, 0), None);
        assert!(c.steps_vec().iter().all(|&s| s == FixedFormat::new(20, 5).step()));
    }

    #[test]
    fn float32_controller_is_passthrough() {
        let c = ScaleController::fixed(16, FixedFormat::FLOAT32, FixedFormat::FLOAT32);
        assert!(c.steps_vec().iter().all(|&s| s == 0.0));
        assert!(c.maxvs_vec().iter().all(|&m| m == 0.0));
    }

    #[test]
    fn update_kinds_get_up_format() {
        let c = ScaleController::fixed(8, FixedFormat::new(10, 3), FixedFormat::new(12, 0));
        // kind order: w b z h dw db dz dh
        assert_eq!(c.format(0).total_bits, 12); // w
        assert_eq!(c.format(1).total_bits, 12); // b
        assert_eq!(c.format(2).total_bits, 10); // z
        assert_eq!(c.format(7).total_bits, 10); // dh
        assert_eq!(c.format(0).int_bits, 0);
        assert_eq!(c.format(2).int_bits, 3);
    }

    #[test]
    fn group_count_follows_the_graph_not_a_layer_constant() {
        // a 3-hidden-layer topology (4 compute layers) yields 32 groups;
        // the kind-format cycle repeats per layer row
        let c = ScaleController::fixed(32, FixedFormat::new(10, 3), FixedFormat::new(12, 0));
        assert_eq!(c.n_groups(), 32);
        for row in 0..4 {
            assert_eq!(c.format(row * 8).total_bits, 12); // w
            assert_eq!(c.format(row * 8 + 2).total_bits, 10); // z
        }
    }

    #[test]
    fn dynamic_controller_updates_on_interval() {
        let mut c = ScaleController::dynamic(
            8,
            FixedFormat::new(10, 2),
            FixedFormat::new(12, 2),
            1e-4,
            100, // examples
        );
        // overflowing every group
        c.observe_matrix(&overflow(8, 50.0, 60.0, 100.0));
        assert_eq!(c.after_batch(64, 0), None); // 64 < 100 examples
        c.observe_matrix(&overflow(8, 50.0, 60.0, 100.0));
        let moves = c.after_batch(64, 1).expect("tick after 128 examples");
        assert_eq!(moves, 8); // every group scaled up
        assert!(c.int_bits_vec().iter().all(|&b| b == 3));
        assert_eq!(c.decisions_log.len(), 8);
    }

    #[test]
    fn quiet_groups_gain_precision() {
        let mut c = ScaleController::dynamic(
            8,
            FixedFormat::new(10, 2),
            FixedFormat::new(12, 2),
            1e-4,
            10,
        );
        c.observe_matrix(&overflow(8, 0.0, 0.0, 10_000.0));
        c.after_batch(10, 0).unwrap();
        assert!(c.int_bits_vec().iter().all(|&b| b == 1));
    }

    #[test]
    fn overflow_rate_exactly_at_threshold_holds() {
        // the paper's rule is strict: scale up only when rate > max_rate,
        // scale down only when half_rate < max_rate. A group sitting
        // EXACTLY on the boundary on both counts must hold.
        let mut c = ScaleController::dynamic(
            8,
            FixedFormat::new(10, 2),
            FixedFormat::new(12, 2),
            0.01,
            10,
        );
        // rate = 100/10_000 = max exactly; half_rate = max exactly
        c.observe_matrix(&overflow(8, 100.0, 100.0, 10_000.0));
        let moves = c.after_batch(10, 0).unwrap();
        assert_eq!(moves, 0);
        assert!(c.int_bits_vec().iter().all(|&b| b == 2));
        // one count above the boundary scales up
        c.observe_matrix(&overflow(8, 101.0, 101.0, 10_000.0));
        assert_eq!(c.after_batch(10, 1).unwrap(), 8);
        assert!(c.int_bits_vec().iter().all(|&b| b == 3));
        // half_rate one count below the boundary scales down
        c.observe_matrix(&overflow(8, 0.0, 99.0, 10_000.0));
        assert_eq!(c.after_batch(10, 2).unwrap(), 8);
        assert!(c.int_bits_vec().iter().all(|&b| b == 2));
    }

    #[test]
    fn single_group_controller_works() {
        // degenerate but legal: one group (kind 0 = w → storage format)
        let mut c = ScaleController::dynamic(
            1,
            FixedFormat::new(10, 2),
            FixedFormat::new(12, 2),
            1e-4,
            10,
        );
        assert_eq!(c.n_groups(), 1);
        assert_eq!(c.format(0).total_bits, 12);
        c.observe_matrix(&overflow(1, 50.0, 60.0, 100.0));
        assert_eq!(c.after_batch(10, 0), Some(1));
        assert_eq!(c.int_bits_vec(), vec![3]);
        assert_eq!(c.decisions_log, vec![(0, 0, 3)]);
    }

    #[test]
    fn adopt_int_bits_transfers_warmup_scales() {
        let mut c =
            ScaleController::dynamic(8, FixedFormat::new(10, 0), FixedFormat::new(12, 0), 1e-4, 10);
        c.adopt_int_bits(&[5, 4, 3, 2, 1, 0, -1, -2]);
        assert_eq!(c.int_bits_vec(), vec![5, 4, 3, 2, 1, 0, -1, -2]);
        // widths preserved
        assert_eq!(c.format(0).total_bits, 12);
        assert_eq!(c.format(2).total_bits, 10);
    }

    #[test]
    fn repeated_adoption_is_idempotent() {
        let mut c =
            ScaleController::dynamic(8, FixedFormat::new(10, 0), FixedFormat::new(12, 0), 1e-4, 10);
        let learned = [5, 4, 3, 2, 1, 0, -1, -2];
        c.adopt_int_bits(&learned);
        let first: Vec<_> = (0..8).map(|g| c.format(g)).collect();
        c.adopt_int_bits(&learned);
        let second: Vec<_> = (0..8).map(|g| c.format(g)).collect();
        assert_eq!(first, second);
        // adoption does not count as a scale move and leaves no log entry
        assert!(c.decisions_log.is_empty());
        // and does not disturb the accumulated-but-unticked counters:
        // a quiet interval after adoption still scales down normally
        c.observe_matrix(&overflow(8, 0.0, 0.0, 10_000.0));
        assert_eq!(c.after_batch(10, 0).unwrap(), 8);
        assert_eq!(c.int_bits_vec(), vec![4, 3, 2, 1, 0, -1, -2, -3]);
    }

    #[test]
    #[should_panic]
    fn adoption_with_wrong_group_count_panics() {
        let mut c = ScaleController::fixed(8, FixedFormat::new(10, 0), FixedFormat::new(12, 0));
        c.adopt_int_bits(&[1, 2, 3]);
    }

    #[test]
    #[should_panic(expected = "overflow matrix shape")]
    fn shape_mismatch_panics() {
        let mut c = ScaleController::fixed(16, FixedFormat::FLOAT32, FixedFormat::FLOAT32);
        c.observe_matrix(&Tensor::zeros(&[3, 3]));
    }
}
