//! Parameter sweeps: the machinery behind every paper figure.
//!
//! A sweep is a base [`ExperimentConfig`] plus a list of variants; the
//! runner executes each variant on ONE shared [`Backend`] (so the PJRT
//! backend's compile cache — and any future backend state worth keeping —
//! is reused across tens of runs) and reports normalized final test
//! errors: the paper's own presentation (every figure divides by the
//! dataset's float32 baseline error).

use crate::config::ExperimentConfig;
use crate::coordinator::trainer::{RunResult, Trainer};
use crate::runtime::Backend;

/// One sweep point: a label and the config to run.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub label: String,
    pub cfg: ExperimentConfig,
}

/// Result row of a sweep.
#[derive(Clone, Debug)]
pub struct SweepRow {
    pub label: String,
    pub test_error: f64,
    /// error / baseline error (the paper's normalized final test error).
    pub normalized: f64,
    pub wallclock: std::time::Duration,
    pub result: RunResult,
}

/// Run `baseline` first (float32 reference), then every point; returns
/// (baseline error, rows with normalized errors).
pub fn run_sweep(
    backend: &mut dyn Backend,
    baseline: &ExperimentConfig,
    points: &[SweepPoint],
    verbose: bool,
) -> crate::Result<(f64, Vec<SweepRow>)> {
    // `&mut *backend` reborrows so the one backend serves every run
    let mut t = Trainer::new(&mut *backend, baseline.clone());
    t.verbose = verbose;
    let base = t.run()?;
    drop(t);
    let base_err = base.test_error.max(1e-9);
    if verbose {
        eprintln!(
            "[sweep] baseline '{}' error {:.4} ({:.1?})",
            baseline.name, base.test_error, base.wallclock
        );
    }

    let mut rows = Vec::with_capacity(points.len());
    for p in points {
        let mut t = Trainer::new(&mut *backend, p.cfg.clone());
        t.verbose = verbose;
        let r = t.run()?;
        drop(t);
        if verbose {
            eprintln!(
                "[sweep] {} error {:.4} (x{:.2} baseline, {:.1?})",
                p.label,
                r.test_error,
                r.test_error / base_err,
                r.wallclock
            );
        }
        rows.push(SweepRow {
            label: p.label.clone(),
            test_error: r.test_error,
            normalized: r.test_error / base_err,
            wallclock: r.wallclock,
            result: r,
        });
    }
    Ok((base.test_error, rows))
}
