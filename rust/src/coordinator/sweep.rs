//! Sweep data model: the machinery behind every paper figure.
//!
//! A sweep is a base [`ExperimentConfig`] (the float32 baseline) plus a
//! list of variant points. [`Session::sweep`](super::Session::sweep)
//! runs the baseline first, fans the points across its worker pool, and
//! reports normalized final test errors: the paper's own presentation
//! (every figure divides by the dataset's float32 baseline error).
//!
//! This module holds the plain data types; the scheduling lives in
//! [`session`](super::session) and the serializable form in
//! [`report`](super::report).

use super::trainer::RunResult;
use crate::config::ExperimentConfig;

/// One sweep point: a label and the config to run.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub label: String,
    pub cfg: ExperimentConfig,
}

/// Result row of a sweep.
#[derive(Clone, Debug)]
pub struct SweepRow {
    pub label: String,
    pub test_error: f64,
    /// error / baseline error (the paper's normalized final test error).
    pub normalized: f64,
    pub wallclock: std::time::Duration,
    pub result: RunResult,
}

impl SweepRow {
    /// Build a row from a finished run, normalizing against the sweep's
    /// baseline error (floored so a perfect baseline cannot divide by
    /// zero).
    pub fn from_result(label: String, result: RunResult, baseline_error: f64) -> SweepRow {
        SweepRow {
            label,
            test_error: result.test_error,
            normalized: result.test_error / baseline_error.max(1e-9),
            wallclock: result.wallclock,
            result,
        }
    }
}

/// Everything a finished sweep reports: the baseline run and one row
/// per point, in the order the points were given (regardless of the
/// worker count that executed them).
#[derive(Clone, Debug)]
pub struct SweepOutcome {
    pub baseline: RunResult,
    pub rows: Vec<SweepRow>,
}

impl SweepOutcome {
    /// The float32 reference error every row is normalized by.
    pub fn baseline_error(&self) -> f64 {
        self.baseline.test_error
    }
}
