//! The training loop: drives one experiment over any [`Backend`].
//!
//! One [`Trainer`] owns a full run: dataset synthesis, parameter init
//! (quantized onto the storage grid), the minibatch loop feeding the
//! backend's train step, the paper's LR/momentum schedules, the dynamic
//! fixed point scale controller, periodic evaluation, and the final test
//! error. The numeric work is entirely behind the
//! [`Backend`](crate::runtime::Backend) trait — the native backend runs
//! it in pure Rust, the PJRT backend on compiled artifacts (DESIGN.md
//! §Backends) — so this loop is written once and the sweeps/benches are
//! backend-agnostic.
//!
//! The trainer is crate-internal machinery: experiments are started
//! through [`Session`](super::Session), which owns backend construction
//! (via [`crate::runtime::BackendSpec`]) and fans progress out to the
//! attached [`RunObserver`](super::RunObserver)s. The trainer itself
//! never prints; it emits typed events.
//!
//! Dynamic fixed point warmup (paper 9.3): "We find the initial scaling
//! factors by training with a higher precision format. Once those scaling
//! factors are found, we reinitialize the model parameters." When
//! `warmup_steps > 0`, the trainer first runs a 31-bit dynamic phase with
//! a fast update interval, adopts the learned per-group exponents, then
//! reinitializes parameters and trains at the target bit-widths.

use super::metrics::Metrics;
use super::observer::{Observers, RunMeta, RunRole};
use super::scale_ctrl::ScaleController;
use crate::config::{Arithmetic, ExperimentConfig};
use crate::data::{Batcher, Dataset};
use crate::error::Context;
use crate::runtime::{Backend, ModelInfo, StepParams};
use crate::tensor::Pcg32;

/// RNG stream tags. Every stochastic choice in a run derives from the
/// experiment seed through forked PCG32 streams; these constants name
/// each fork so the warmup phase and the main phase can never silently
/// diverge in which stream feeds which consumer:
///
/// * [`RNG_FORK_INIT`] — forked off the phase's root stream for
///   parameter initialization ([`Backend::init_state`]).
/// * [`RNG_FORK_BATCHER`] — forked off the phase's root stream for
///   minibatch shuffling ([`Batcher::new`]).
/// * [`WARMUP_SEED_XOR`] — xor'd into the experiment seed to derive the
///   warmup phase's root stream, so warmup sees the same *distributions*
///   (same fork tags) over decorrelated draws, and the post-warmup
///   reinitialization (paper 9.3) starts from fresh parameters.
/// * [`STOCHASTIC_SITE_SEED`](crate::golden::STOCHASTIC_SITE_SEED) — the
///   one stream *not* derived from the experiment seed: the base of the
///   counter-based stochastic-rounding streams inside a train step
///   (`golden::GoldenQ`). A fixed constant, so rounding noise is a
///   property of the quantization site, never of the run.
pub const RNG_FORK_INIT: u64 = 0x1217;
pub const RNG_FORK_BATCHER: u64 = 0xBA7C;
pub const WARMUP_SEED_XOR: u64 = 0xAAAA;

/// Everything a finished run reports.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub config_name: String,
    /// Sweep-point label (equals `config_name` for standalone runs).
    pub label: String,
    /// Which backend executed the run ("native" / "pjrt").
    pub backend_name: String,
    /// Final test error rate in [0, 1].
    pub test_error: f64,
    /// Final (tail-averaged) training loss.
    pub train_loss: f32,
    pub metrics: Metrics,
    /// Per-group int_bits at the end (scale trajectory lives in metrics).
    pub final_int_bits: Vec<i32>,
    pub steps_run: usize,
    pub wallclock: std::time::Duration,
    /// Per-site GEMM lowering-outcome counters over the whole run
    /// (`"<layer>.<site>"` keys), empty for backends without a layer
    /// graph — the report's `int_gemm_sites` section.
    pub int_gemm_sites: std::collections::BTreeMap<String, crate::tensor::ops::GemmSiteCounts>,
}

/// Drives one experiment end to end on a borrowed backend. Constructed
/// only by [`Session`](super::Session) (single runs and sweep workers).
pub(crate) struct Trainer<'a> {
    backend: &'a mut dyn Backend,
    cfg: ExperimentConfig,
    meta: RunMeta,
    observers: &'a Observers,
}

impl<'a> Trainer<'a> {
    pub(crate) fn new(
        backend: &'a mut dyn Backend,
        cfg: ExperimentConfig,
        label: String,
        role: RunRole,
        observers: &'a Observers,
    ) -> Trainer<'a> {
        let meta = RunMeta {
            name: cfg.name.clone(),
            label,
            backend: backend.name().to_string(),
            steps: cfg.train.steps,
            role,
        };
        Trainer { backend, cfg, meta, observers }
    }

    /// Run the experiment and return its results.
    pub(crate) fn run(&mut self) -> crate::Result<RunResult> {
        let started = std::time::Instant::now();
        self.cfg.validate()?;
        let model = self.backend.begin_run(&self.cfg)?;

        // Dataset: test size rounded up to whole eval batches so padded
        // wrap-around examples never exist (exact error counts).
        let n_test = self.cfg.data.n_test.div_ceil(model.eval_batch) * model.eval_batch;
        let root_rng = Pcg32::seeded(self.cfg.train.seed);
        let dataset = Dataset::generate(
            &self.cfg.data.dataset,
            self.cfg.data.n_train,
            n_test,
            &root_rng,
        )?;

        // Scale controller sized from the model graph's group table,
        // with optional high-precision warmup.
        let mut ctrl = self.make_controller(model.n_groups);
        if let Arithmetic::Dynamic { warmup_steps, .. } = self.cfg.arithmetic {
            if warmup_steps > 0 {
                let learned = self.warmup(&model, &dataset, warmup_steps)?;
                ctrl.adopt_int_bits(&learned);
                self.observers.warmup_end(&self.meta, &learned);
            }
        }

        // Parameter init (reinitialized after warmup per the paper).
        let mut init_rng = root_rng.fork(RNG_FORK_INIT);
        self.backend.init_state(&ctrl, &mut init_rng)?;

        // Train loop.
        let mut metrics = Metrics::default();
        let mut batcher = Batcher::new(
            &dataset.train,
            model.train_batch,
            model.n_classes,
            root_rng.fork(RNG_FORK_BATCHER),
        );
        let steps = self.cfg.train.steps;
        for t in 0..steps {
            let (x, y) = batcher.next_batch();
            let hp = self.step_params(t);
            let out = self.backend.train_step(&ctrl, &x, &y, &hp).context("train step")?;
            crate::ensure!(out.loss.is_finite(), "non-finite loss at step {t}: {}", out.loss);
            metrics.record_loss(t, out.loss);
            self.observers.step(&self.meta, t, out.loss);
            ctrl.observe_matrix(&out.overflow);
            if let Some(moves) = ctrl.after_batch(model.train_batch, t) {
                metrics.record_scale_moves(t, moves);
                self.observers.scale_move(&self.meta, t, moves);
            }
            if self.cfg.train.eval_every > 0
                && t + 1 != steps
                && (t + 1) % self.cfg.train.eval_every == 0
            {
                let err = self.evaluate(&model, &ctrl, &dataset)?;
                metrics.record_eval(t, err);
                self.observers.eval(&self.meta, t, out.loss, err);
            }
        }

        // Final evaluation.
        let err = self.evaluate(&model, &ctrl, &dataset)?;
        let last_step = steps.saturating_sub(1);
        metrics.record_eval(last_step, err);
        self.observers.eval(
            &self.meta,
            last_step,
            metrics.final_loss().unwrap_or(f32::NAN),
            err,
        );

        let result = RunResult {
            config_name: self.cfg.name.clone(),
            label: self.meta.label.clone(),
            backend_name: self.backend.name().to_string(),
            test_error: err,
            train_loss: metrics.tail_loss(10).unwrap_or(f32::NAN),
            final_int_bits: ctrl.int_bits_vec(),
            metrics,
            steps_run: steps,
            wallclock: started.elapsed(),
            int_gemm_sites: self.backend.int_gemm_sites(),
        };
        self.observers.run_end(&self.meta, &result);
        Ok(result)
    }

    /// Resolve the schedules at step `t` into per-step backend inputs.
    fn step_params(&self, t: usize) -> StepParams {
        let tc = &self.cfg.train;
        StepParams {
            lr: tc.lr_at(t),
            momentum: tc.momentum_at(t),
            max_norm: tc.max_norm,
            dropout_input: tc.dropout_input,
            dropout_hidden: tc.dropout_hidden,
            t,
        }
    }

    fn make_controller(&self, n_groups: usize) -> ScaleController {
        let (comp_fmt, up_fmt) = self.cfg.arithmetic.initial_formats();
        match self.cfg.arithmetic {
            Arithmetic::Dynamic { max_overflow_rate, update_every_examples, .. } => {
                ScaleController::dynamic(
                    n_groups,
                    comp_fmt,
                    up_fmt,
                    max_overflow_rate,
                    update_every_examples,
                )
            }
            _ => ScaleController::fixed(n_groups, comp_fmt, up_fmt),
        }
    }

    /// High-precision warmup phase for dynamic fixed point: run a 31-bit
    /// dynamic controller with a short update interval so the exponents
    /// converge quickly; return the learned per-group int_bits.
    fn warmup(
        &mut self,
        model: &ModelInfo,
        dataset: &Dataset,
        warmup_steps: usize,
    ) -> crate::Result<Vec<i32>> {
        let (init_int, max_rate) = match self.cfg.arithmetic {
            Arithmetic::Dynamic { init_int_bits, max_overflow_rate, .. } => {
                (init_int_bits, max_overflow_rate)
            }
            _ => unreachable!("warmup only runs for dynamic arithmetic"),
        };
        let wide = crate::arith::FixedFormat::new(31, init_int);
        let mut ctrl = ScaleController::dynamic(
            model.n_groups,
            wide,
            wide,
            max_rate,
            (model.train_batch * 4).max(1), // tick every 4 batches
        );
        let root_rng = Pcg32::seeded(self.cfg.train.seed ^ WARMUP_SEED_XOR);
        let mut rng = root_rng.fork(RNG_FORK_INIT);
        self.backend.init_state(&ctrl, &mut rng)?;
        let mut batcher = Batcher::new(
            &dataset.train,
            model.train_batch,
            model.n_classes,
            root_rng.fork(RNG_FORK_BATCHER),
        );
        for t in 0..warmup_steps {
            let (x, y) = batcher.next_batch();
            let hp = self.step_params(t);
            let out = self.backend.train_step(&ctrl, &x, &y, &hp).context("warmup step")?;
            // a diverged warmup must fail fast: NaN activations would read
            // as zero overflow and teach the controller garbage exponents
            crate::ensure!(
                out.loss.is_finite(),
                "non-finite loss at warmup step {t}: {}",
                out.loss
            );
            ctrl.observe_matrix(&out.overflow);
            ctrl.after_batch(model.train_batch, t);
        }
        Ok(ctrl.int_bits_vec())
    }

    /// Full test-set evaluation; returns the error rate.
    fn evaluate(
        &mut self,
        model: &ModelInfo,
        ctrl: &ScaleController,
        dataset: &Dataset,
    ) -> crate::Result<f64> {
        let mut errors = 0usize;
        let mut total = 0usize;
        for (x, y, n_real) in
            Batcher::eval_batches(&dataset.test, model.eval_batch, model.n_classes)
        {
            debug_assert_eq!(n_real, model.eval_batch, "test size is batch-aligned");
            errors += self.backend.eval_errors(ctrl, &x, &y, n_real).context("eval step")?;
            total += n_real;
        }
        Ok(errors as f64 / total as f64)
    }
}
