//! The training coordinator: drives the compiled train/eval steps.
//!
//! One [`Trainer`] owns a full run: dataset synthesis, parameter init
//! (quantized onto the storage grid), the minibatch loop feeding the
//! compiled train step, the paper's LR/momentum schedules, the dynamic
//! fixed point scale controller, periodic evaluation, and the final test
//! error. Python never runs here — the artifacts were AOT-compiled by
//! `make artifacts`.
//!
//! Dynamic fixed point warmup (paper 9.3): "We find the initial scaling
//! factors by training with a higher precision format. Once those scaling
//! factors are found, we reinitialize the model parameters." When
//! `warmup_steps > 0`, the trainer first runs a 31-bit dynamic phase with
//! a fast update interval, adopts the learned per-group exponents, then
//! reinitializes parameters and trains at the target bit-widths.

use anyhow::Context;
use xla::Literal;

use super::metrics::Metrics;
use super::scale_ctrl::ScaleController;
use crate::arith::{FixedFormat, Quantizer};
use crate::config::{Arithmetic, ExperimentConfig};
use crate::data::{Batcher, Dataset};
use crate::runtime::literal_util::{
    literal_to_scalar, literal_to_tensor, scalar, slice_to_literal, tensor_to_literal,
};
use crate::runtime::{Engine, Executable, Manifest, ModelInfo};
use crate::tensor::{Pcg32, Tensor};

/// Everything a finished run reports.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub config_name: String,
    /// Final test error rate in [0, 1].
    pub test_error: f64,
    /// Final (tail-averaged) training loss.
    pub train_loss: f32,
    pub metrics: Metrics,
    /// Per-group int_bits at the end (scale trajectory lives in metrics).
    pub final_int_bits: Vec<i32>,
    pub steps_run: usize,
    pub wallclock: std::time::Duration,
}

/// Model state: parameter + velocity literals in manifest order.
///
/// State lives as PJRT literals, not host tensors: each step's outputs are
/// fed straight back as the next step's inputs, so parameters never make a
/// host round-trip on the training path (EXPERIMENTS.md §Perf, L3).
pub struct State {
    params: Vec<Literal>,
    vels: Vec<Literal>,
}

impl State {
    /// Initialize from the manifest specs, quantizing every parameter
    /// onto its group's storage grid (the device does so on every
    /// *update*; doing it at init keeps step 0 consistent).
    fn init(
        model: &ModelInfo,
        ctrl: &ScaleController,
        rng: &mut Pcg32,
    ) -> crate::Result<State> {
        let mut params = Vec::with_capacity(model.params.len());
        let mut vels = Vec::with_capacity(model.params.len());
        for spec in &model.params {
            let mut t = spec.init.realize(&spec.shape, rng);
            Quantizer::from_format(ctrl.format(spec.group())).apply_slice(t.data_mut());
            params.push(tensor_to_literal(&t)?);
            vels.push(tensor_to_literal(&Tensor::zeros(&spec.shape))?);
        }
        Ok(State { params, vels })
    }
}

/// Drives one experiment end to end.
pub struct Trainer<'a> {
    pub engine: &'a Engine,
    pub manifest: &'a Manifest,
    pub cfg: ExperimentConfig,
    /// Print progress lines to stderr.
    pub verbose: bool,
}

impl<'a> Trainer<'a> {
    pub fn new(engine: &'a Engine, manifest: &'a Manifest, cfg: ExperimentConfig) -> Self {
        Trainer { engine, manifest, cfg, verbose: false }
    }

    /// Run the experiment and return its results.
    pub fn run(&self) -> crate::Result<RunResult> {
        let started = std::time::Instant::now();
        self.cfg.validate()?;
        let model = self.manifest.model(&self.cfg.model)?;
        let mode = self.cfg.arithmetic.mode();
        let train_exe =
            self.engine.load_cached(self.manifest.artifact(&self.cfg.model, mode, "train")?)?;
        let eval_exe =
            self.engine.load_cached(self.manifest.artifact(&self.cfg.model, mode, "eval")?)?;

        // Dataset: test size rounded up to whole eval batches so padded
        // wrap-around examples never exist (exact error counts).
        let n_test = self.cfg.data.n_test.div_ceil(model.eval_batch) * model.eval_batch;
        let root_rng = Pcg32::seeded(self.cfg.train.seed);
        let dataset = Dataset::generate(
            &self.cfg.data.dataset,
            self.cfg.data.n_train,
            n_test,
            &root_rng,
        )?;

        // Scale controller, with optional high-precision warmup.
        let mut ctrl = self.make_controller(model.n_layers);
        if let Arithmetic::Dynamic { warmup_steps, bits_comp: _, .. } = self.cfg.arithmetic {
            if warmup_steps > 0 {
                let learned = self.warmup(model, train_exe.as_ref(), &dataset, warmup_steps)?;
                ctrl.adopt_int_bits(&learned);
                if self.verbose {
                    eprintln!("[{}] warmup adopted int_bits {learned:?}", self.cfg.name);
                }
            }
        }

        // Parameter init (reinitialized after warmup per the paper).
        let mut init_rng = root_rng.fork(0x1217);
        let mut state = State::init(model, &ctrl, &mut init_rng)?;

        // Train loop.
        let mut metrics = Metrics::default();
        let mut batcher = Batcher::new(
            &dataset.train,
            model.train_batch,
            model.n_classes,
            root_rng.fork(0xBA7C),
        );
        let steps = self.cfg.train.steps;
        for t in 0..steps {
            let (x, y) = batcher.next_batch();
            let out = self.run_train_step(train_exe.as_ref(), model, &mut state, &ctrl, &x, &y, t)?;
            metrics.record_loss(t, out.loss);
            ctrl.observe_matrix(&out.overflow);
            if let Some(moves) = ctrl.after_batch(model.train_batch, t) {
                metrics.record_scale_moves(t, moves);
            }
            if self.cfg.train.eval_every > 0
                && t + 1 != steps
                && (t + 1) % self.cfg.train.eval_every == 0
            {
                let err = self.evaluate(eval_exe.as_ref(), model, &state, &ctrl, &dataset)?;
                metrics.record_eval(t, err);
                if self.verbose {
                    eprintln!(
                        "[{}] step {t}: loss {:.4} err {:.4}",
                        self.cfg.name, out.loss, err
                    );
                }
            }
        }

        // Final evaluation.
        let err = self.evaluate(eval_exe.as_ref(), model, &state, &ctrl, &dataset)?;
        metrics.record_eval(steps.saturating_sub(1), err);

        Ok(RunResult {
            config_name: self.cfg.name.clone(),
            test_error: err,
            train_loss: metrics.tail_loss(10).unwrap_or(f32::NAN),
            final_int_bits: ctrl.int_bits_vec(),
            metrics,
            steps_run: steps,
            wallclock: started.elapsed(),
        })
    }

    fn make_controller(&self, n_layers: usize) -> ScaleController {
        let (comp_fmt, up_fmt) = self.cfg.arithmetic.initial_formats();
        match self.cfg.arithmetic {
            Arithmetic::Dynamic { max_overflow_rate, update_every_examples, .. } => {
                ScaleController::dynamic(
                    n_layers,
                    comp_fmt,
                    up_fmt,
                    max_overflow_rate,
                    update_every_examples,
                )
            }
            _ => ScaleController::fixed(n_layers, comp_fmt, up_fmt),
        }
    }

    /// High-precision warmup phase for dynamic fixed point: run a 31-bit
    /// dynamic controller with a short update interval so the exponents
    /// converge quickly; return the learned per-group int_bits.
    fn warmup(
        &self,
        model: &ModelInfo,
        train_exe: &Executable,
        dataset: &Dataset,
        warmup_steps: usize,
    ) -> crate::Result<Vec<i32>> {
        let init_int = match self.cfg.arithmetic {
            Arithmetic::Dynamic { init_int_bits, .. } => init_int_bits,
            _ => unreachable!("warmup only runs for dynamic arithmetic"),
        };
        let max_rate = match self.cfg.arithmetic {
            Arithmetic::Dynamic { max_overflow_rate, .. } => max_overflow_rate,
            _ => unreachable!(),
        };
        let wide = FixedFormat::new(31, init_int);
        let mut ctrl = ScaleController::dynamic(
            model.n_layers,
            wide,
            wide,
            max_rate,
            (model.train_batch * 4).max(1), // tick every 4 batches
        );
        let root_rng = Pcg32::seeded(self.cfg.train.seed ^ 0xAAAA);
        let mut rng = root_rng.fork(0x1217);
        let mut state = State::init(model, &ctrl, &mut rng)?;
        let mut batcher = Batcher::new(
            &dataset.train,
            model.train_batch,
            model.n_classes,
            root_rng.fork(0xBA7C),
        );
        for t in 0..warmup_steps {
            let (x, y) = batcher.next_batch();
            let out = self.run_train_step(train_exe, model, &mut state, &ctrl, &x, &y, t)?;
            ctrl.observe_matrix(&out.overflow);
            ctrl.after_batch(model.train_batch, t);
        }
        Ok(ctrl.int_bits_vec())
    }

    /// Assemble inputs, execute one train step, scatter outputs back.
    fn run_train_step(
        &self,
        exe: &Executable,
        model: &ModelInfo,
        state: &mut State,
        ctrl: &ScaleController,
        x: &Tensor,
        y: &Tensor,
        t: usize,
    ) -> crate::Result<StepOut> {
        let tc = &self.cfg.train;
        let n_p = model.params.len();

        // Per-step inputs (x, y, scalars, scale vectors) are freshly built;
        // parameters/velocities are borrowed from the previous step's
        // outputs — no host round-trip for model state.
        // x arrives in dataset layout; the artifact wants [batch, ...model
        // input shape] — same bytes (e.g. 28×28×1 → 784 for pi_mlp).
        let mut x_shape = vec![model.train_batch];
        x_shape.extend_from_slice(&model.input_shape);
        let mut rates = vec![tc.dropout_hidden; model.n_layers];
        rates[0] = tc.dropout_input;
        let fresh: Vec<Literal> = vec![
            slice_to_literal(x.data(), &x_shape)?,
            tensor_to_literal(y)?,
            scalar(tc.lr_at(t)),
            scalar(tc.momentum_at(t)),
            scalar(tc.max_norm),
            scalar((t as u32 % (1 << 24)) as f32), // in-graph dropout seed
            slice_to_literal(&rates, &[model.n_layers])?,
            slice_to_literal(&ctrl.steps_vec(), &[model.n_groups])?,
            slice_to_literal(&ctrl.maxvs_vec(), &[model.n_groups])?,
        ];
        let inputs: Vec<&Literal> = state
            .params
            .iter()
            .chain(state.vels.iter())
            .chain(fresh.iter())
            .collect();

        let mut outputs = exe.run(&inputs).context("train step")?;

        let loss = literal_to_scalar(&outputs[2 * n_p])?;
        anyhow::ensure!(loss.is_finite(), "non-finite loss at step {t}: {loss}");
        let overflow = literal_to_tensor(&outputs[2 * n_p + 1])?;
        // feed the updated state straight into the next step
        state.vels = outputs.split_off(n_p).into_iter().take(n_p).collect();
        state.params = outputs;
        Ok(StepOut { loss, overflow })
    }

    /// Full test-set evaluation; returns the error rate.
    pub fn evaluate(
        &self,
        exe: &Executable,
        model: &ModelInfo,
        state: &State,
        ctrl: &ScaleController,
        dataset: &Dataset,
    ) -> crate::Result<f64> {
        let steps_v = ctrl.steps_vec();
        let maxvs_v = ctrl.maxvs_vec();
        let mut errors = 0.0f64;
        let mut total = 0usize;
        for (x, y, n_real) in
            Batcher::eval_batches(&dataset.test, model.eval_batch, model.n_classes)
        {
            debug_assert_eq!(n_real, model.eval_batch, "test size is batch-aligned");
            let mut x_shape = vec![model.eval_batch];
            x_shape.extend_from_slice(&model.input_shape);
            let fresh: Vec<Literal> = vec![
                slice_to_literal(x.data(), &x_shape)?,
                tensor_to_literal(&y)?,
                slice_to_literal(&steps_v, &[model.n_groups])?,
                slice_to_literal(&maxvs_v, &[model.n_groups])?,
            ];
            let inputs: Vec<&Literal> = state.params.iter().chain(fresh.iter()).collect();
            let out = exe.run(&inputs).context("eval step")?;
            errors += literal_to_scalar(&out[0])? as f64;
            total += n_real;
        }
        Ok(errors / total as f64)
    }
}

struct StepOut {
    loss: f32,
    overflow: Tensor,
}
