//! [`Session`]: the experiment-driving entry point.
//!
//! A session owns backend construction (through a
//! [`BackendSpec`](crate::runtime::BackendSpec)), a set of attached
//! [`RunObserver`]s, and a `jobs` knob for sweep parallelism:
//!
//! * [`Session::run`] executes one experiment on the session's own
//!   backend (built lazily and reused across runs, so the PJRT compile
//!   cache amortizes over a whole suite).
//! * [`Session::sweep`] executes the paper's figure machinery: the
//!   float32 baseline first, then every point fanned across `jobs`
//!   worker threads. Each worker constructs its *own* backend from the
//!   spec (backends are stateful and not `Send`), claims points off a
//!   shared counter, and writes its rows into per-point slots — so the
//!   returned rows are in deterministic point order and, because every
//!   run is fully seeded and the native kernels preserve accumulation
//!   order at any thread count, bit-identical to a `jobs = 1` sweep.
//!
//! Worker threads multiply with the native backend's own matmul threads
//! (`LPDNN_THREADS`); on a saturated host cap one of the two.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use super::observer::{Observers, RunObserver, RunRole};
use super::sweep::{SweepOutcome, SweepPoint, SweepRow};
use super::trainer::{RunResult, Trainer};
use crate::config::ExperimentConfig;
use crate::error::Context;
use crate::runtime::{Backend, BackendSpec};
use crate::tensor::Tensor;

/// Owns how experiments execute: backend construction, observers,
/// sweep parallelism. See the module docs.
pub struct Session {
    spec: BackendSpec,
    jobs: usize,
    observers: Observers,
    /// Lazily-built engine for single runs and `jobs = 1` sweeps.
    backend: Option<Box<dyn Backend>>,
}

impl Session {
    pub fn new(spec: BackendSpec) -> Session {
        Session { spec, jobs: 1, observers: Observers::new(), backend: None }
    }

    /// Session for the backend named by `LPDNN_BACKEND` (unset = native).
    pub fn from_env() -> crate::Result<Session> {
        Ok(Session::new(BackendSpec::from_env()?))
    }

    /// Set the sweep worker count (clamped to ≥ 1). `jobs = 1` runs
    /// points sequentially on the session's own backend.
    pub fn with_jobs(mut self, jobs: usize) -> Session {
        self.jobs = jobs.max(1);
        self
    }

    /// Attach an observer (builder form).
    pub fn with_observer(mut self, obs: Arc<dyn RunObserver>) -> Session {
        self.observers.push(obs);
        self
    }

    /// Attach an observer.
    pub fn add_observer(&mut self, obs: Arc<dyn RunObserver>) {
        self.observers.push(obs);
    }

    pub fn spec(&self) -> &BackendSpec {
        &self.spec
    }

    pub fn jobs(&self) -> usize {
        self.jobs
    }

    /// Name of the session's backend (constructs it on first call).
    pub fn backend_name(&mut self) -> crate::Result<&'static str> {
        Ok(self.backend()?.name())
    }

    /// Whether the session's backend can run `model` (constructs the
    /// backend on first call).
    pub fn supports_model(&mut self, model: &str) -> crate::Result<bool> {
        Ok(self.backend()?.supports_model(model))
    }

    fn backend(&mut self) -> crate::Result<&mut dyn Backend> {
        if self.backend.is_none() {
            self.backend = Some(self.spec.create()?);
        }
        Ok(self.backend.as_mut().unwrap().as_mut())
    }

    /// The session backend's current parameters in manifest order. The
    /// backend is retained across [`Session::run`] calls, so after a run
    /// this is the trained state — what `lpdnn train --save` checkpoints.
    pub fn params_host(&mut self) -> crate::Result<Vec<Tensor>> {
        self.backend()?.params_host()
    }

    /// Run one experiment end to end and return its results.
    pub fn run(&mut self, cfg: ExperimentConfig) -> crate::Result<RunResult> {
        let label = cfg.name.clone();
        self.run_inner(cfg, label, RunRole::Standalone)
    }

    fn run_inner(
        &mut self,
        cfg: ExperimentConfig,
        label: String,
        role: RunRole,
    ) -> crate::Result<RunResult> {
        let observers = self.observers.clone();
        let backend = self.backend()?;
        Trainer::new(backend, cfg, label, role, &observers).run()
    }

    /// Run `baseline` first (the float32 reference), then every point
    /// across the session's worker pool. Rows come back in point order,
    /// normalized by the baseline error, and are bit-identical for any
    /// `jobs` value (see module docs).
    pub fn sweep(
        &mut self,
        baseline: &ExperimentConfig,
        points: &[SweepPoint],
    ) -> crate::Result<SweepOutcome> {
        let base = self
            .run_inner(baseline.clone(), baseline.name.clone(), RunRole::Baseline)
            .with_context(|| format!("sweep baseline '{}'", baseline.name))?;
        let base_err = base.test_error;

        let jobs = self.jobs.min(points.len().max(1));
        let rows = if jobs <= 1 {
            let mut rows = Vec::with_capacity(points.len());
            for p in points {
                let r = self
                    .run_inner(p.cfg.clone(), p.label.clone(), RunRole::Point)
                    .with_context(|| format!("sweep point '{}'", p.label))?;
                rows.push(SweepRow::from_result(p.label.clone(), r, base_err));
            }
            rows
        } else {
            self.sweep_parallel(points, base_err, jobs)?
        };
        Ok(SweepOutcome { baseline: base, rows })
    }

    /// The worker pool: `jobs` threads, each with its own backend built
    /// from the spec, claiming points off a shared counter.
    fn sweep_parallel(
        &self,
        points: &[SweepPoint],
        base_err: f64,
        jobs: usize,
    ) -> crate::Result<Vec<SweepRow>> {
        let spec = &self.spec;
        let observers = &self.observers;
        let next = AtomicUsize::new(0);
        // Once any point fails, workers stop claiming new points (runs
        // already in flight finish normally) — matching the `jobs = 1`
        // path, which stops at the first failure.
        let failed = AtomicBool::new(false);
        let slots: Vec<Mutex<Option<crate::Result<SweepRow>>>> =
            points.iter().map(|_| Mutex::new(None)).collect();

        std::thread::scope(|s| {
            for _ in 0..jobs {
                s.spawn(|| {
                    // One engine per worker, reused across every point
                    // this worker claims.
                    let mut backend: Option<Box<dyn Backend>> = None;
                    loop {
                        if failed.load(Ordering::Relaxed) {
                            break;
                        }
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= points.len() {
                            break;
                        }
                        let point = &points[i];
                        let row = (|| -> crate::Result<SweepRow> {
                            if backend.is_none() {
                                backend = Some(spec.create()?);
                            }
                            let be = backend.as_mut().unwrap();
                            let r = Trainer::new(
                                be.as_mut(),
                                point.cfg.clone(),
                                point.label.clone(),
                                RunRole::Point,
                                observers,
                            )
                            .run()?;
                            Ok(SweepRow::from_result(point.label.clone(), r, base_err))
                        })();
                        if row.is_err() {
                            failed.store(true, Ordering::Relaxed);
                        }
                        *slots[i].lock().unwrap() = Some(row);
                    }
                });
            }
        });

        // Collect in point order; surface the first failure (by point
        // order, not completion order) with its label attached. Claims
        // are monotonic in the point index, so unexecuted (None) slots
        // can only sit after the failed point and are never reached.
        let mut rows = Vec::with_capacity(points.len());
        for (slot, p) in slots.into_iter().zip(points) {
            match slot.into_inner().unwrap() {
                Some(row) => {
                    rows.push(row.with_context(|| format!("sweep point '{}'", p.label))?)
                }
                None => crate::bail!(
                    "sweep point '{}' was not executed (sweep aborted after a failure)",
                    p.label
                ),
            }
        }
        Ok(rows)
    }
}

/// One-line oversubscription warning when two parallelism knobs
/// multiply past the machine's cores, naming both knobs so the user
/// knows which to cap — e.g. `--dp-workers 4` × `LPDNN_THREADS=8` on a
/// 16-core host. Returns `None` when the product fits (or when `cores`
/// is unknown, i.e. 0): oversubscription never changes bits here, it
/// only wastes wall-clock, so this is advice, not an error.
pub fn oversubscription_warning(
    a_name: &str,
    a: usize,
    b_name: &str,
    b: usize,
    cores: usize,
) -> Option<String> {
    if cores == 0 || a.saturating_mul(b) <= cores {
        return None;
    }
    Some(format!(
        "warning: {a_name}={a} x {b_name}={b} = {} threads oversubscribes {cores} \
         available cores; cap {a_name} or {b_name} (results are bit-identical either way)",
        a * b
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Arithmetic, DataConfig, TrainConfig};

    #[test]
    fn oversubscription_warning_names_both_knobs() {
        let w = oversubscription_warning("--dp-workers", 4, "LPDNN_THREADS", 8, 16)
            .expect("32 threads on 16 cores warns");
        assert!(w.contains("--dp-workers=4"), "{w}");
        assert!(w.contains("LPDNN_THREADS=8"), "{w}");
        assert!(w.contains("32 threads"), "{w}");
        assert!(w.contains("16 available cores"), "{w}");
    }

    #[test]
    fn oversubscription_warning_is_quiet_when_it_fits() {
        assert!(oversubscription_warning("--dp-workers", 2, "LPDNN_THREADS", 8, 16).is_none());
        assert!(oversubscription_warning("--jobs", 1, "LPDNN_THREADS", 16, 16).is_none());
        // unknown core count: stay quiet rather than guess
        assert!(oversubscription_warning("--dp-workers", 64, "LPDNN_THREADS", 64, 0).is_none());
    }

    fn tiny_cfg(name: &str) -> ExperimentConfig {
        ExperimentConfig {
            name: name.into(),
            model: "pi_mlp".into(),
            arithmetic: Arithmetic::Float32,
            train: TrainConfig { steps: 2, seed: 7, ..Default::default() },
            data: DataConfig { dataset: "clusters".into(), n_train: 128, n_test: 64 },
            ..Default::default()
        }
    }

    #[test]
    fn session_runs_and_reuses_its_backend() {
        let mut s = Session::new(BackendSpec::native());
        assert_eq!(s.jobs(), 1);
        assert_eq!(s.backend_name().unwrap(), "native");
        assert!(s.supports_model("pi_mlp").unwrap());
        // conv topologies run natively since the shape-aware layer graph
        assert!(s.supports_model("conv").unwrap());
        assert!(s.supports_model("pi_conv").unwrap());
        assert!(!s.supports_model("resnet").unwrap());
        let a = s.run(tiny_cfg("sess-a")).unwrap();
        let b = s.run(tiny_cfg("sess-b")).unwrap();
        assert_eq!(a.label, "sess-a");
        assert!(a.test_error.is_finite() && b.test_error.is_finite());
    }

    #[test]
    fn jobs_clamped_to_at_least_one() {
        let s = Session::new(BackendSpec::native()).with_jobs(0);
        assert_eq!(s.jobs(), 1);
    }

    #[test]
    fn sweep_rows_keep_point_order_under_parallelism() {
        let baseline = tiny_cfg("order-base");
        let points: Vec<SweepPoint> = (0..5)
            .map(|i| {
                let mut cfg = tiny_cfg(&format!("order-{i}"));
                cfg.arithmetic =
                    Arithmetic::Fixed { bits_comp: 20, bits_up: 20, int_bits: 5 };
                SweepPoint { label: format!("{i}"), cfg }
            })
            .collect();
        let mut s = Session::new(BackendSpec::native()).with_jobs(3);
        let out = s.sweep(&baseline, &points).unwrap();
        assert_eq!(out.baseline.config_name, "order-base");
        let labels: Vec<&str> = out.rows.iter().map(|r| r.label.as_str()).collect();
        assert_eq!(labels, ["0", "1", "2", "3", "4"]);
        assert!(out.rows.iter().all(|r| r.normalized.is_finite()));
    }

    #[test]
    fn sweep_point_failure_names_the_point() {
        let baseline = tiny_cfg("fail-base");
        let mut bad = tiny_cfg("fail-point");
        bad.model = "conv".into(); // conv stages cannot consume the flat
        bad.data.dataset = "clusters".into(); // clusters dataset: validate fails
        let points = vec![SweepPoint { label: "bad".into(), cfg: bad }];
        let mut s = Session::new(BackendSpec::native()).with_jobs(2);
        let err = s.sweep(&baseline, &points).unwrap_err();
        assert!(format!("{err:#}").contains("sweep point 'bad'"));
    }
}
