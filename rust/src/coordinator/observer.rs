//! [`RunObserver`]: the typed event stream every run emits.
//!
//! The old experiment API reported progress through a `verbose: bool`
//! and ad-hoc `eprintln!`/CSV plumbing scattered over the trainer, the
//! sweep runner, the CLI and the benches. This module replaces all of
//! that with one trait: the trainer emits typed events (`on_step`,
//! `on_eval`, `on_scale_move`, `on_warmup_end`, `on_run_end`) and the
//! consumers — the stderr progress printer, the `--loss-csv` writer,
//! test collectors — are observer implementations attached to a
//! [`Session`](super::Session).
//!
//! Observers are `Send + Sync` and take `&self` (interior mutability
//! where state is needed), so one observer instance can watch every
//! worker of a parallel sweep. Events from concurrent runs interleave;
//! the [`RunMeta`] passed with every event says which run it belongs to.

use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use super::trainer::RunResult;

/// How a run relates to the sweep machinery (observers use this to
/// format and contextualize events).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RunRole {
    /// A standalone `session.run` experiment.
    Standalone,
    /// The float32 reference run a sweep executes first.
    Baseline,
    /// One point of a sweep.
    Point,
}

/// Identity of the run an event belongs to.
#[derive(Clone, Debug)]
pub struct RunMeta {
    /// The experiment config's name.
    pub name: String,
    /// Sweep-point label (equals `name` for standalone runs).
    pub label: String,
    /// Which backend executes the run ("native" / "pjrt").
    pub backend: String,
    /// Total SGD steps the run will take.
    pub steps: usize,
    /// Standalone run, sweep baseline, or sweep point.
    pub role: RunRole,
}

/// A consumer of run events. All methods default to no-ops so an
/// observer implements only what it cares about.
pub trait RunObserver: Send + Sync {
    /// One SGD step finished (main phase only, not warmup).
    fn on_step(&self, _run: &RunMeta, _step: usize, _loss: f32) {}

    /// A test-set evaluation finished (periodic and final). `loss` is
    /// the most recent minibatch loss at evaluation time.
    fn on_eval(&self, _run: &RunMeta, _step: usize, _loss: f32, _test_error: f64) {}

    /// The scale controller moved `moves` scaling factors at its tick
    /// after `step` (dynamic fixed point only).
    fn on_scale_move(&self, _run: &RunMeta, _step: usize, _moves: usize) {}

    /// The high-precision warmup phase (paper 9.3) finished and the run
    /// adopted the learned per-group `int_bits`.
    fn on_warmup_end(&self, _run: &RunMeta, _int_bits: &[i32]) {}

    /// The run finished; `result` is exactly what the session returns.
    fn on_run_end(&self, _run: &RunMeta, _result: &RunResult) {}
}

/// A shared, cheaply clonable set of observers that fans every event
/// out to each of them in attachment order.
#[derive(Clone, Default)]
pub struct Observers {
    list: Vec<Arc<dyn RunObserver>>,
}

impl Observers {
    pub fn new() -> Observers {
        Observers::default()
    }

    pub fn push(&mut self, obs: Arc<dyn RunObserver>) {
        self.list.push(obs);
    }

    pub fn len(&self) -> usize {
        self.list.len()
    }

    pub fn is_empty(&self) -> bool {
        self.list.is_empty()
    }

    pub fn step(&self, run: &RunMeta, step: usize, loss: f32) {
        for o in &self.list {
            o.on_step(run, step, loss);
        }
    }

    pub fn eval(&self, run: &RunMeta, step: usize, loss: f32, test_error: f64) {
        for o in &self.list {
            o.on_eval(run, step, loss, test_error);
        }
    }

    pub fn scale_move(&self, run: &RunMeta, step: usize, moves: usize) {
        for o in &self.list {
            o.on_scale_move(run, step, moves);
        }
    }

    pub fn warmup_end(&self, run: &RunMeta, int_bits: &[i32]) {
        for o in &self.list {
            o.on_warmup_end(run, int_bits);
        }
    }

    pub fn run_end(&self, run: &RunMeta, result: &RunResult) {
        for o in &self.list {
            o.on_run_end(run, result);
        }
    }
}

/// The stderr progress printer: what `Trainer.verbose` and the sweep
/// runner's eprintln lines used to produce, as an observer.
#[derive(Default)]
pub struct StderrProgress {
    /// Baseline error of the enclosing sweep, once its run ends (point
    /// lines then print the paper's normalized ratio).
    baseline_error: Mutex<Option<f64>>,
}

impl StderrProgress {
    pub fn new() -> StderrProgress {
        StderrProgress::default()
    }
}

impl RunObserver for StderrProgress {
    fn on_eval(&self, run: &RunMeta, step: usize, loss: f32, test_error: f64) {
        eprintln!("[{}] step {step}: loss {loss:.4} err {test_error:.4}", run.name);
    }

    fn on_warmup_end(&self, run: &RunMeta, int_bits: &[i32]) {
        eprintln!("[{}] warmup adopted int_bits {int_bits:?}", run.name);
    }

    fn on_run_end(&self, run: &RunMeta, result: &RunResult) {
        match run.role {
            RunRole::Baseline => {
                *self.baseline_error.lock().unwrap() = Some(result.test_error.max(1e-9));
                eprintln!(
                    "[sweep] baseline '{}' error {:.4} ({:.1?})",
                    run.name, result.test_error, result.wallclock
                );
            }
            RunRole::Point => {
                if let Some(base) = *self.baseline_error.lock().unwrap() {
                    eprintln!(
                        "[sweep] {} error {:.4} (x{:.2} baseline, {:.1?})",
                        run.label,
                        result.test_error,
                        result.test_error / base,
                        result.wallclock
                    );
                } else {
                    eprintln!(
                        "[sweep] {} error {:.4} ({:.1?})",
                        run.label, result.test_error, result.wallclock
                    );
                }
            }
            RunRole::Standalone => {
                eprintln!(
                    "[{}] error {:.4} ({:.1?})",
                    run.label, result.test_error, result.wallclock
                );
            }
        }
    }
}

/// The `--loss-csv` writer as an observer: writes one `step,loss` CSV
/// per finished run. In per-label mode (sweeps) each run's file name is
/// the base path suffixed with the run's label, so a sweep emits one
/// curve per point instead of clobbering a single file.
pub struct LossCsvObserver {
    base: PathBuf,
    suffix_labels: bool,
    /// Write failures, in arrival order (observer callbacks cannot
    /// propagate errors; the driver checks after the run — see
    /// [`first_error`](LossCsvObserver::first_error)).
    errors: Mutex<Vec<String>>,
}

impl LossCsvObserver {
    /// Write every finished run to exactly `base` (single-run mode).
    pub fn new(base: impl AsRef<Path>) -> LossCsvObserver {
        LossCsvObserver {
            base: base.as_ref().to_path_buf(),
            suffix_labels: false,
            errors: Mutex::new(Vec::new()),
        }
    }

    /// Write `<stem>-<label>.<ext>` per run (sweep mode).
    pub fn per_label(base: impl AsRef<Path>) -> LossCsvObserver {
        LossCsvObserver {
            base: base.as_ref().to_path_buf(),
            suffix_labels: true,
            errors: Mutex::new(Vec::new()),
        }
    }

    /// The first write failure, if any — callers propagate it once the
    /// run/sweep is over so a missing CSV cannot pass silently.
    pub fn first_error(&self) -> Option<String> {
        self.errors.lock().unwrap().first().cloned()
    }

    /// Resolve the output path for a run label.
    pub fn path_for(&self, label: &str) -> PathBuf {
        if !self.suffix_labels {
            return self.base.clone();
        }
        let stem = self.base.file_stem().and_then(|s| s.to_str()).unwrap_or("loss");
        let ext = self.base.extension().and_then(|s| s.to_str()).unwrap_or("csv");
        let clean: String = label
            .chars()
            .map(|c| {
                if c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.') {
                    c
                } else {
                    '_'
                }
            })
            .collect();
        self.base.with_file_name(format!("{stem}-{clean}.{ext}"))
    }
}

impl RunObserver for LossCsvObserver {
    fn on_run_end(&self, run: &RunMeta, result: &RunResult) {
        let path = self.path_for(&run.label);
        if let Err(e) = result.metrics.write_loss_csv(&path) {
            let msg = format!("cannot write loss csv {path:?}: {e:#}");
            eprintln!("[loss-csv] {msg}");
            self.errors.lock().unwrap().push(msg);
        }
    }
}

/// One recorded event (see [`RecordingObserver`]).
#[derive(Clone, Debug, PartialEq)]
pub enum ObserverEvent {
    Step { label: String, step: usize, loss: f32 },
    Eval { label: String, step: usize, test_error: f64 },
    ScaleMove { label: String, step: usize, moves: usize },
    WarmupEnd { label: String, int_bits: Vec<i32> },
    RunEnd { label: String, test_error: f64 },
}

/// Records every event in arrival order — the collector the tests (and
/// any programmatic consumer) use instead of scraping stderr.
#[derive(Default)]
pub struct RecordingObserver {
    events: Mutex<Vec<ObserverEvent>>,
}

impl RecordingObserver {
    pub fn new() -> RecordingObserver {
        RecordingObserver::default()
    }

    /// Drain the recorded events.
    pub fn take(&self) -> Vec<ObserverEvent> {
        std::mem::take(&mut *self.events.lock().unwrap())
    }

    fn record(&self, ev: ObserverEvent) {
        self.events.lock().unwrap().push(ev);
    }
}

impl RunObserver for RecordingObserver {
    fn on_step(&self, run: &RunMeta, step: usize, loss: f32) {
        self.record(ObserverEvent::Step { label: run.label.clone(), step, loss });
    }

    fn on_eval(&self, run: &RunMeta, step: usize, _loss: f32, test_error: f64) {
        self.record(ObserverEvent::Eval { label: run.label.clone(), step, test_error });
    }

    fn on_scale_move(&self, run: &RunMeta, step: usize, moves: usize) {
        self.record(ObserverEvent::ScaleMove { label: run.label.clone(), step, moves });
    }

    fn on_warmup_end(&self, run: &RunMeta, int_bits: &[i32]) {
        self.record(ObserverEvent::WarmupEnd {
            label: run.label.clone(),
            int_bits: int_bits.to_vec(),
        });
    }

    fn on_run_end(&self, run: &RunMeta, result: &RunResult) {
        self.record(ObserverEvent::RunEnd {
            label: run.label.clone(),
            test_error: result.test_error,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn observers_fan_out_in_order() {
        let rec = Arc::new(RecordingObserver::new());
        let mut obs = Observers::new();
        assert!(obs.is_empty());
        obs.push(rec.clone());
        obs.push(rec.clone());
        assert_eq!(obs.len(), 2);
        let meta = RunMeta {
            name: "t".into(),
            label: "t".into(),
            backend: "native".into(),
            steps: 1,
            role: RunRole::Standalone,
        };
        obs.step(&meta, 0, 1.5);
        let events = rec.take();
        // both attached copies saw the event
        assert_eq!(events.len(), 2);
        assert_eq!(events[0], ObserverEvent::Step { label: "t".into(), step: 0, loss: 1.5 });
    }

    #[test]
    fn loss_csv_paths_suffix_labels() {
        let single = LossCsvObserver::new("/tmp/out.csv");
        assert_eq!(single.path_for("anything"), PathBuf::from("/tmp/out.csv"));
        let per = LossCsvObserver::per_label("/tmp/out.csv");
        assert_eq!(per.path_for("10"), PathBuf::from("/tmp/out-10.csv"));
        // hostile label characters are sanitized
        assert_eq!(per.path_for("a/b c"), PathBuf::from("/tmp/out-a_b_c.csv"));
    }

    #[test]
    fn recording_observer_captures_all_event_kinds() {
        let rec = RecordingObserver::new();
        let meta = RunMeta {
            name: "r".into(),
            label: "p1".into(),
            backend: "native".into(),
            steps: 2,
            role: RunRole::Point,
        };
        rec.on_scale_move(&meta, 3, 2);
        rec.on_warmup_end(&meta, &[3, 4]);
        let events = rec.take();
        assert_eq!(
            events,
            vec![
                ObserverEvent::ScaleMove { label: "p1".into(), step: 3, moves: 2 },
                ObserverEvent::WarmupEnd { label: "p1".into(), int_bits: vec![3, 4] },
            ]
        );
        assert!(rec.take().is_empty(), "take drains");
    }
}
