//! Run metrics: in-memory history + CSV/JSON export.

use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

use crate::config::json::Json;

/// Time series recorded during one training run.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    /// (step, train minibatch loss)
    pub losses: Vec<(usize, f32)>,
    /// (step, test error rate in [0,1])
    pub evals: Vec<(usize, f64)>,
    /// (step, #scale moves at that controller tick)
    pub scale_moves: Vec<(usize, usize)>,
}

impl Metrics {
    pub fn record_loss(&mut self, step: usize, loss: f32) {
        self.losses.push((step, loss));
    }

    pub fn record_eval(&mut self, step: usize, err: f64) {
        self.evals.push((step, err));
    }

    pub fn record_scale_moves(&mut self, step: usize, moves: usize) {
        self.scale_moves.push((step, moves));
    }

    pub fn final_loss(&self) -> Option<f32> {
        self.losses.last().map(|&(_, l)| l)
    }

    pub fn final_error(&self) -> Option<f64> {
        self.evals.last().map(|&(_, e)| e)
    }

    /// Mean loss over the last `n` recorded steps (smoother than a single
    /// minibatch loss).
    pub fn tail_loss(&self, n: usize) -> Option<f32> {
        if self.losses.is_empty() {
            return None;
        }
        let tail = &self.losses[self.losses.len().saturating_sub(n)..];
        Some(tail.iter().map(|&(_, l)| l).sum::<f32>() / tail.len() as f32)
    }

    /// Write the loss curve as CSV (`step,loss`).
    pub fn write_loss_csv(&self, path: impl AsRef<Path>) -> crate::Result<()> {
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "step,loss")?;
        for (s, l) in &self.losses {
            writeln!(f, "{s},{l}")?;
        }
        Ok(())
    }

    /// Full metrics as a JSON document.
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert(
            "losses".to_string(),
            Json::Array(
                self.losses
                    .iter()
                    .map(|&(s, l)| Json::Array(vec![Json::Num(s as f64), Json::Num(l as f64)]))
                    .collect(),
            ),
        );
        m.insert(
            "evals".to_string(),
            Json::Array(
                self.evals
                    .iter()
                    .map(|&(s, e)| Json::Array(vec![Json::Num(s as f64), Json::Num(e)]))
                    .collect(),
            ),
        );
        m.insert(
            "scale_moves".to_string(),
            Json::Array(
                self.scale_moves
                    .iter()
                    .map(|&(s, n)| Json::Array(vec![Json::Num(s as f64), Json::Num(n as f64)]))
                    .collect(),
            ),
        );
        Json::Object(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes() {
        let mut m = Metrics::default();
        for i in 0..10 {
            m.record_loss(i, 1.0 / (i + 1) as f32);
        }
        m.record_eval(9, 0.125);
        assert_eq!(m.final_error(), Some(0.125));
        assert_eq!(m.final_loss(), Some(0.1));
        let t = m.tail_loss(2).unwrap();
        assert!((t - (1.0 / 9.0 + 0.1) / 2.0).abs() < 1e-6);
    }

    #[test]
    fn tail_loss_handles_short_history() {
        let mut m = Metrics::default();
        assert_eq!(m.tail_loss(5), None);
        m.record_loss(0, 2.0);
        assert_eq!(m.tail_loss(5), Some(2.0));
    }

    #[test]
    fn json_export_parses_back() {
        let mut m = Metrics::default();
        m.record_loss(0, 1.5);
        m.record_eval(0, 0.5);
        m.record_scale_moves(3, 2);
        let j = m.to_json();
        let reparsed = crate::config::json::parse(&j.to_string()).unwrap();
        assert_eq!(reparsed, j);
    }

    #[test]
    fn csv_written() {
        let mut m = Metrics::default();
        m.record_loss(0, 1.0);
        m.record_loss(1, 0.5);
        let path = std::env::temp_dir().join("lpdnn_test_loss.csv");
        m.write_loss_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("step,loss"));
        assert!(text.contains("1,0.5"));
        let _ = std::fs::remove_file(&path);
    }
}
