//! Dynamic scaling demo: watch the paper's section 5 controller work.
//!
//! ```bash
//! cargo run --release --example dynamic_scaling_demo
//! ```
//!
//! Trains pi_mlp (native backend — self-contained; set
//! `LPDNN_BACKEND=pjrt` for the compiled path) under dynamic fixed point
//! with a very frequent update interval and prints the per-group scaling
//! factors (int_bits) as they adapt: weighted-sum groups grow their range
//! while gradient groups shrink toward high precision — and keep
//! shrinking as the gradients themselves shrink during training (the
//! paper's "the gradients diminish during the training, so do their
//! ranges", section 10).

use lpdnn::config::{Arithmetic, ExperimentConfig};
use lpdnn::coordinator::Session;
use lpdnn::runtime::ModelInfo;

fn main() -> lpdnn::Result<()> {
    let mut session = Session::from_env()?;
    // group names are topology metadata — identical on both backends
    let model = ModelInfo::builtin("pi_mlp").expect("builtin pi_mlp");
    println!("backend: {}", session.backend_name()?);

    let mut cfg = ExperimentConfig::default();
    cfg.name = "scaling-demo".into();
    cfg.arithmetic = Arithmetic::Dynamic {
        bits_comp: 12,
        bits_up: 14,
        max_overflow_rate: 1e-4,
        update_every_examples: 512, // tick every 8 batches: visible motion
        init_int_bits: 3,
        warmup_steps: 0, // start from a deliberately bad uniform guess
    };
    cfg.train.steps = 240;
    cfg.data.n_train = 2048;

    let result = session.run(cfg)?;

    println!("groups ({}):", model.n_groups);
    for (i, name) in model.group_names.iter().enumerate() {
        print!("{name:>8}");
        if (i + 1) % 8 == 0 {
            println!();
        }
    }

    println!("\nscale trajectory (int_bits per group after each controller tick):");
    // reconstruct per-tick snapshots from the decisions log is internal;
    // print the summary the metrics carry instead
    println!("{:>6} {:>12}", "step", "scale moves");
    for &(step, moves) in &result.metrics.scale_moves {
        println!("{step:>6} {moves:>12}");
    }

    println!("\nfinal int_bits by group:");
    for (name, bits) in model.group_names.iter().zip(&result.final_int_bits) {
        let kind = name.split('.').nth(1).unwrap_or("?");
        let note = match kind {
            "w" | "b" => "parameter storage",
            "z" | "h" => "forward signal",
            _ => "gradient",
        };
        println!("  {name:>8}: int_bits {bits:>3}  ({note})");
    }

    let grads: Vec<i32> = model
        .group_names
        .iter()
        .zip(&result.final_int_bits)
        .filter(|(n, _)| n.contains(".d"))
        .map(|(_, &b)| b)
        .collect();
    let fwd: Vec<i32> = model
        .group_names
        .iter()
        .zip(&result.final_int_bits)
        .filter(|(n, _)| n.ends_with(".z") || n.ends_with(".h"))
        .map(|(_, &b)| b)
        .collect();
    let mean = |v: &[i32]| v.iter().sum::<i32>() as f64 / v.len().max(1) as f64;
    println!(
        "\nmean int_bits — forward signals: {:.1}, gradients: {:.1}",
        mean(&fwd),
        mean(&grads)
    );
    println!("(the paper's section 10 asymmetry: gradients need far less range)");
    println!("\nfinal test error: {:.2}%", 100.0 * result.test_error);
    Ok(())
}
