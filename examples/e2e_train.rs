//! End-to-end driver: the full stack on a real workload.
//!
//! ```bash
//! cargo run --release --example e2e_train
//! ```
//!
//! Trains the permutation-invariant maxout MLP (~560k parameters) for
//! several hundred steps on the synthetic digits corpus under THREE
//! arithmetics — float32, float16, dynamic fixed point 10/12 — logging
//! the loss curve of each and writing them to `e2e_loss_curves.csv`.
//! This is the run recorded in EXPERIMENTS.md §End-to-end.
//!
//! The backend comes from `LPDNN_BACKEND` (default: the pure-Rust native
//! engine, which needs nothing beyond `cargo run`; `pjrt` proves all
//! three compiled layers compose — rust coordinator (L3) feeding the
//! AOT-compiled jax maxout network (L2) whose hot path runs the Pallas
//! quantize / fused-maxout kernels (L1), via the PJRT CPU client).

use std::io::Write;
use std::sync::Arc;

use lpdnn::config::{Arithmetic, ExperimentConfig};
use lpdnn::coordinator::{RunResult, Session, StderrProgress};

fn run(
    session: &mut Session,
    name: &str,
    arith: Arithmetic,
    steps: usize,
) -> lpdnn::Result<RunResult> {
    let mut cfg = ExperimentConfig::default();
    cfg.name = name.into();
    cfg.arithmetic = arith;
    cfg.train.steps = steps;
    cfg.train.lr_start = 0.15;
    cfg.train.lr_end = 0.01;
    cfg.train.dropout_input = 0.1;
    cfg.train.dropout_hidden = 0.25;
    cfg.train.eval_every = 50;
    cfg.data.n_train = 4096;
    cfg.data.n_test = 1024;
    session.run(cfg)
}

fn main() -> lpdnn::Result<()> {
    let steps: usize = std::env::var("E2E_STEPS").ok().and_then(|s| s.parse().ok()).unwrap_or(300);
    // progress lines (periodic evals, run ends) go through the observer
    let mut session = Session::from_env()?.with_observer(Arc::new(StderrProgress::new()));
    println!("backend: {}", session.backend_name()?);
    println!("model: pi_mlp (2x maxout-128/k4 + softmax, ~560k params)");
    println!("data: 4096 train / 1024 test synthetic digits, batch 64, {steps} steps\n");

    let f32r = run(&mut session, "e2e-float32", Arithmetic::Float32, steps)?;
    let halfr = run(&mut session, "e2e-float16", Arithmetic::Half, steps)?;
    let dynr = run(
        &mut session,
        "e2e-dynamic-10-12",
        Arithmetic::Dynamic {
            bits_comp: 10,
            bits_up: 12,
            max_overflow_rate: 1e-4,
            update_every_examples: 4096,
            init_int_bits: 3,
            warmup_steps: 40,
        },
        steps,
    )?;

    // combined loss-curve CSV
    let path = "e2e_loss_curves.csv";
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "step,float32,float16,dynamic_10_12")?;
    for i in 0..steps {
        writeln!(
            f,
            "{},{},{},{}",
            i, f32r.metrics.losses[i].1, halfr.metrics.losses[i].1, dynr.metrics.losses[i].1
        )?;
    }

    let mut table = lpdnn::bench_support::Table::new(&[
        "arithmetic", "comp bits", "up bits", "test error", "normalized", "wallclock",
    ]);
    let base = f32r.test_error.max(1e-9);
    for (label, comp, up, r) in [
        ("float32", "32", "32", &f32r),
        ("float16", "16", "16", &halfr),
        ("dynamic fixed point", "10", "12", &dynr),
    ] {
        table.row(&[
            label.to_string(),
            comp.to_string(),
            up.to_string(),
            format!("{:.2}%", 100.0 * r.test_error),
            format!("{:.2}x", r.test_error / base),
            format!("{:.1?}", r.wallclock),
        ]);
    }
    println!("\n=== end-to-end results (paper Table 3 analogue) ===");
    table.print();
    println!("loss curves written to {path}");

    // quick textual loss-curve comparison (every steps/10 steps)
    println!("\nloss curve (sampled):");
    println!("{:>6} {:>10} {:>10} {:>10}", "step", "float32", "float16", "dyn10/12");
    for i in (0..steps).step_by((steps / 10).max(1)) {
        println!(
            "{:>6} {:>10.4} {:>10.4} {:>10.4}",
            i, f32r.metrics.losses[i].1, halfr.metrics.losses[i].1, dynr.metrics.losses[i].1
        );
    }
    Ok(())
}
