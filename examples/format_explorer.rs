//! Format explorer: the numeric formats themselves, host-side.
//!
//! ```bash
//! cargo run --release --example format_explorer
//! ```
//!
//! No artifacts needed — this example exercises the from-scratch software
//! arithmetic substrate (`lpdnn::arith`): fixed point grids and mantissa
//! bit patterns, rounding modes, IEEE binary16 conversion, and the
//! quantization error / overflow-rate trade-off the radix position
//! controls (the intuition behind the paper's Figure 1).

use lpdnn::arith::{FixedFormat, QFixed, Quantizer, RoundMode};
use lpdnn::arith::float16::{f32_to_f16_bits, half_roundtrip};
use lpdnn::bench_support::Table;
use lpdnn::tensor::Pcg32;

fn main() {
    println!("=== fixed point mantissas (QFixed) ===");
    let fmt = FixedFormat::new(12, 3); // Q3.8
    let mut t = Table::new(&["value", "mantissa", "bits", "reconstructed"]);
    for v in [0.0f32, 1.0, -1.0, 3.14159, 7.96875, 8.5, -9.0] {
        let q = QFixed::from_f32(v, fmt, RoundMode::HalfAway, 0.0);
        t.row(&[
            format!("{v}"),
            format!("{}", q.mantissa),
            format!("{:012b}", (q.mantissa as i16 as u16) & 0xFFF),
            format!("{}", q.to_f32()),
        ]);
    }
    println!("format {fmt}: step {}, range [-{}, {})", fmt.step(), fmt.maxv(), fmt.maxv());
    t.print();

    println!("\n=== rounding modes on ties ===");
    let mut t = Table::new(&["x", "half-away", "half-even", "truncate"]);
    for x in [0.5f32, 1.5, 2.5, -2.5] {
        t.row(&[
            format!("{x}"),
            format!("{}", RoundMode::HalfAway.round(x, 0.0)),
            format!("{}", RoundMode::HalfEven.round(x, 0.0)),
            format!("{}", RoundMode::Truncate.round(x, 0.0)),
        ]);
    }
    t.print();

    println!("\n=== IEEE binary16 (paper Table 1: 1+5+10 bits) ===");
    let mut t = Table::new(&["f32", "f16 bits", "roundtrip", "rel err"]);
    for v in [1.0f32, 0.1, 3.141592, 65504.0, 70000.0, 1e-7] {
        let rt = half_roundtrip(v);
        t.row(&[
            format!("{v}"),
            format!("{:#06x}", f32_to_f16_bits(v)),
            format!("{rt}"),
            format!("{:.2e}", ((rt - v) / v).abs()),
        ]);
    }
    t.print();

    println!("\n=== radix position trade-off (the Figure 1 intuition) ===");
    println!("Quantizing N(0, 4) samples with a 12-bit format at each radix:");
    let mut rng = Pcg32::seeded(7);
    let xs: Vec<f32> = (0..100_000).map(|_| rng.normal() * 4.0).collect();
    let mut t = Table::new(&["radix (int bits)", "range", "overflow rate", "RMS error"]);
    for int_bits in 0..9 {
        let q = Quantizer::from_format(FixedFormat::new(12, int_bits));
        let stats = q.stats_only(&xs);
        let mut se = 0.0f64;
        for &x in &xs {
            let e = (q.apply(x) - x) as f64;
            se += e * e;
        }
        t.row(&[
            format!("{int_bits}"),
            format!("±{}", q.maxv),
            format!("{:.4}%", 100.0 * stats.rate()),
            format!("{:.3e}", (se / xs.len() as f64).sqrt()),
        ]);
    }
    t.print();
    println!("Too few integer bits → saturation error dominates;");
    println!("too many → resolution error dominates. The paper finds the");
    println!("sweet spot at radix 5 for its networks (section 9.2).");
}
