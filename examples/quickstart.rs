//! Quickstart: train one Maxout network under low precision arithmetic.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Runs entirely on the self-contained native backend — no artifacts, no
//! Python. A [`Session`] owns backend construction; it trains the pi_mlp
//! maxout network on the synthetic digits dataset under the paper's
//! headline arithmetic (dynamic fixed point, 10-bit computations /
//! 12-bit parameter updates) and prints the final test error next to a
//! float32 baseline. Set `LPDNN_BACKEND=pjrt` (with a `--features pjrt`
//! build and `make artifacts`) to run the identical experiment on the
//! compiled path.

use lpdnn::config::{Arithmetic, ExperimentConfig};
use lpdnn::coordinator::Session;

fn main() -> lpdnn::Result<()> {
    // The session builds the backend described by LPDNN_BACKEND
    // (default: native) and reuses it across both runs below.
    let mut session = Session::from_env()?;
    println!("backend: {}", session.backend_name()?);

    // A baseline config: pi_mlp on the digits dataset, 120 SGD steps.
    let mut cfg = ExperimentConfig::default();
    cfg.name = "quickstart-float32".into();
    cfg.backend = session.spec().kind();
    cfg.train.steps = 120;
    cfg.data.n_train = 2048;
    cfg.data.n_test = 512;

    println!("== float32 baseline ==");
    let base = session.run(cfg.clone())?;
    println!("test error: {:.2}%  ({:.1?})", 100.0 * base.test_error, base.wallclock);

    // The paper's headline: 10-bit computations, 12-bit parameter updates,
    // per-group scales managed online by the rust controller (section 5).
    cfg.name = "quickstart-dynamic-10-12".into();
    cfg.arithmetic = Arithmetic::Dynamic {
        bits_comp: 10,
        bits_up: 12,
        max_overflow_rate: 1e-4, // paper: 0.01%
        update_every_examples: 2048,
        init_int_bits: 3,
        warmup_steps: 30,
    };

    println!("\n== dynamic fixed point (10-bit comp / 12-bit up) ==");
    let dynr = session.run(cfg)?;
    println!("test error: {:.2}%  ({:.1?})", 100.0 * dynr.test_error, dynr.wallclock);
    println!("normalized vs float32: {:.2}x", dynr.test_error / base.test_error.max(1e-9));
    println!(
        "scale moves during training: {}",
        dynr.metrics.scale_moves.iter().map(|&(_, n)| n).sum::<usize>()
    );
    println!("\nPaper Table 3 analogue: dynamic 10/12 trains within a small");
    println!("factor of the float32 baseline — very low precision is enough.");
    Ok(())
}
