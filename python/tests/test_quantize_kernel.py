"""Pallas quantize kernel vs the pure-jnp oracle (the core L1 signal).

hypothesis sweeps shapes, block sizes and format parameters; the kernel must
agree with `ref.quantize_with_stats_ref` exactly (same f32 ops, same
rounding), not just approximately.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import formats as F
from compile.kernels import ref
from compile.kernels.quantize import quantize, quantize_with_stats

RNG = np.random.default_rng(1234)


def _rand(shape, scale=4.0):
    return (RNG.standard_normal(shape) * scale).astype(np.float32)


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(1, 5000),
    total_bits=st.integers(2, 31),
    int_bits=st.integers(-4, 8),
    block=st.sampled_from([64, 1024, 8192]),
)
def test_matches_ref_1d(n, total_bits, int_bits, block):
    x = _rand((n,))
    step = F.step_for(int_bits, total_bits)
    maxv = F.maxv_for(int_bits)
    y, stats = quantize_with_stats(x, step, maxv, block=block)
    yr, statsr = ref.quantize_with_stats_ref(x, step, maxv)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))
    np.testing.assert_array_equal(np.asarray(stats), np.asarray(statsr))


@settings(max_examples=20, deadline=None)
@given(
    shape=st.sampled_from([(3, 5), (64, 784), (4, 64, 128), (1, 1, 1), (2, 3, 4, 5)]),
    total_bits=st.integers(4, 20),
)
def test_matches_ref_nd(shape, total_bits):
    x = _rand(shape)
    step, maxv = F.step_for(2, total_bits), F.maxv_for(2)
    y, stats = quantize_with_stats(x, step, maxv)
    yr, statsr = ref.quantize_with_stats_ref(x, step, maxv)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))
    np.testing.assert_array_equal(np.asarray(stats), np.asarray(statsr))


def test_float32_passthrough_is_exact():
    x = _rand((777,))
    y, stats = quantize_with_stats(x, 0.0, 0.0)
    np.testing.assert_array_equal(np.asarray(y), x)
    assert np.asarray(stats).tolist() == [0.0, 0.0, 777.0]


def test_idempotent():
    """Quantizing an already-quantized tensor is the identity."""
    x = _rand((2048,))
    step, maxv = F.step_for(3, 10), F.maxv_for(3)
    y1 = np.asarray(quantize(x, step, maxv))
    y2 = np.asarray(quantize(y1, step, maxv))
    np.testing.assert_array_equal(y1, y2)


def test_values_on_grid_and_saturated():
    x = _rand((4096,), scale=20.0)
    step, maxv = F.step_for(2, 8), F.maxv_for(2)  # range [-4, 4), step 2^-5
    y = np.asarray(quantize(x, step, maxv))
    k = y / step
    np.testing.assert_allclose(k, np.round(k), atol=1e-6)  # on the grid
    assert y.max() <= maxv - step + 1e-9
    assert y.min() >= -maxv - 1e-9


def test_rounding_is_half_away_from_zero():
    step, maxv = 1.0, 2.0**10
    x = np.array([0.5, -0.5, 1.5, -1.5, 2.5, -2.5], np.float32)
    y = np.asarray(quantize(x, step, maxv))
    np.testing.assert_array_equal(y, [1.0, -1.0, 2.0, -2.0, 3.0, -3.0])


def test_overflow_counters_exact():
    x = np.array([0.0, 1.0, 2.0, 3.9, 4.0, -4.0, -5.0, 100.0], np.float32)
    _, stats = quantize_with_stats(x, F.step_for(2, 8), F.maxv_for(2))  # maxv=4
    n_over, n_half, n_total = np.asarray(stats).tolist()
    assert n_over == 4.0   # 4.0, -4.0, -5.0, 100.0  (|x| >= 4)
    assert n_half == 6.0   # plus 2.0, 3.9           (|x| >= 2)
    assert n_total == 8.0


@pytest.mark.parametrize("total_bits,int_bits", [(10, 3), (12, 0), (20, 5)])
def test_quantization_error_bounded(total_bits, int_bits):
    x = _rand((4096,), scale=1.0)
    step, maxv = F.step_for(int_bits, total_bits), F.maxv_for(int_bits)
    y = np.asarray(quantize(x, step, maxv))
    inside = np.abs(x) < maxv - step
    assert np.max(np.abs(y[inside] - x[inside])) <= step / 2 + 1e-9
