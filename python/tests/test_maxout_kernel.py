"""Fused maxout dense Pallas kernel vs the pure-jnp oracle."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import formats as F
from compile.kernels import ref
from compile.kernels.maxout import _pick_block, maxout_dense

RNG = np.random.default_rng(99)


def _mk(b, i, u, k, wscale=0.1):
    x = (RNG.standard_normal((b, i)) * 2).astype(np.float32)
    w = (RNG.standard_normal((k, i, u)) * wscale).astype(np.float32)
    bias = (RNG.standard_normal((k, u)) * 0.2).astype(np.float32)
    return x, w, bias


@settings(max_examples=25, deadline=None)
@given(
    b=st.sampled_from([1, 8, 64]),
    i=st.sampled_from([16, 49, 784]),
    u=st.sampled_from([10, 128]),
    k=st.integers(1, 5),
    int_bits=st.integers(-2, 6),
    total_bits=st.integers(4, 31),
)
def test_matches_ref(b, i, u, k, int_bits, total_bits):
    x, w, bias = _mk(b, i, u, k)
    step, maxv = F.step_for(int_bits, total_bits), F.maxv_for(int_bits)
    h, amax, stats = maxout_dense(x, w, bias, step, maxv)
    hr, statsr = ref.maxout_dense_ref(x, w, bias, step, maxv)
    # The kernel accumulates the dot products in a different order than the
    # einsum oracle; f32 reassociation can move a weighted sum across a
    # rounding boundary, so agreement is up to ONE quantization step (and
    # exact for the overwhelming majority of entries).
    hn, hrn = np.asarray(h), np.asarray(hr)
    np.testing.assert_allclose(hn, hrn, atol=step + 1e-4, rtol=1e-5)
    # (no exact-match assertion: for very fine steps, e.g. 2^-20, an f32
    # reassociation difference of ~1e-7 relative flips the rounded LSB on
    # a large fraction of entries — bounded by one step, as asserted.)
    # counters likewise: values landing exactly on a counting threshold can
    # tip either way under reassociation.
    sn, srn = np.asarray(stats), np.asarray(statsr)
    tol = max(4.0, 0.002 * float(srn[2]))
    np.testing.assert_allclose(sn, srn, atol=tol)


def test_argmax_routing_matches_oracle():
    x, w, bias = _mk(64, 784, 128, 4)
    step, maxv = F.step_for(3, 12), F.maxv_for(3)
    _, amax, _ = maxout_dense(x, w, bias, step, maxv)
    z = np.einsum("bi,kio->kbo", x, w) + bias[:, None, :]
    zq = np.asarray(ref.quantize_ref(z, step, maxv))
    np.testing.assert_array_equal(np.asarray(amax).astype(int), zq.argmax(axis=0))


def test_float32_passthrough():
    x, w, bias = _mk(8, 49, 10, 3)
    h, _, stats = maxout_dense(x, w, bias, 0.0, 0.0)
    hr, _ = ref.maxout_dense_ref(x, w, bias, 0.0, 0.0)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), atol=1e-6)
    assert np.asarray(stats)[0] == 0 and np.asarray(stats)[1] == 0


def test_block_tiling_invariance():
    """Result must not depend on the chosen block sizes (up to f32 summation
    order: different reduction tilings reassociate the adds, which can move
    a value across a rounding/counting boundary in rare cases)."""
    x, w, bias = _mk(64, 784, 128, 2)
    step, maxv = F.step_for(2, 10), F.maxv_for(2)
    h1, a1, s1 = maxout_dense(x, w, bias, step, maxv, bt=64, ut=128, it=128)
    h2, a2, s2 = maxout_dense(x, w, bias, step, maxv, bt=8, ut=16, it=49)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=step + 1e-6)
    assert (np.asarray(a1) == np.asarray(a2)).mean() > 0.99
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=16)


@given(dim=st.integers(1, 2048), pref=st.integers(1, 256))
@settings(max_examples=60, deadline=None)
def test_pick_block_always_divides(dim, pref):
    bl = _pick_block(dim, pref)
    assert 1 <= bl <= dim and dim % bl == 0 and bl <= max(pref, 1)
