"""AOT pipeline smoke tests: lowering works, manifest is consistent."""

import json
import os

import jax
import pytest

from compile import aot
from compile import model as M


def test_to_hlo_text_roundtrips_simple_fn():
    import jax.numpy as jnp

    def fn(a, b):
        return (a @ b + 1.0,)

    spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    text = aot.to_hlo_text(jax.jit(fn).lower(spec, spec))
    assert "HloModule" in text
    assert "f32[4,4]" in text


@pytest.mark.parametrize("mode", ["fixed", "half"])
def test_pi_mlp_lowers(mode):
    m = M.pi_mlp(units=32, k=2)
    lowered = jax.jit(m.train_step(mode)).lower(*m.train_example_args())
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    # all 6 params + 6 velocities + 8 scalars/vectors = 20 inputs
    assert text.count("parameter(") >= 20


def test_io_name_tables_align_with_example_args():
    m = M.pi_mlp(units=32, k=2)
    inputs, outputs = aot.train_io_names(m)
    assert len(inputs) == len(m.train_example_args())
    n_p = 2 * m.n_layers
    assert outputs[-2:] == ["loss", "overflow"]
    assert len(outputs) == 2 * n_p + 2

    inputs_e, outputs_e = aot.eval_io_names(m)
    assert len(inputs_e) == len(m.eval_example_args())
    assert outputs_e == ["err_count", "loss_sum"]


def test_built_manifest_consistent_with_artifacts():
    """If `make artifacts` has run, every referenced file must exist and
    every model entry must be self-consistent."""
    root = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    mpath = os.path.join(root, "manifest.json")
    if not os.path.exists(mpath):
        pytest.skip("artifacts not built")
    with open(mpath) as f:
        man = json.load(f)
    assert man["version"] == 1
    for key, art in man["artifacts"].items():
        assert os.path.exists(os.path.join(root, art["file"])), key
        model = man["models"][art["model"]]
        n_p = 2 * model["n_layers"]
        if art["graph"] == "train":
            assert len(art["inputs"]) == 2 * n_p + 9
            assert art["outputs"][-2:] == ["loss", "overflow"]
        else:
            assert len(art["inputs"]) == n_p + 4
    for name, model in man["models"].items():
        assert model["n_groups"] == 8 * model["n_layers"]
        assert len(model["group_names"]) == model["n_groups"]
        assert len(model["params"]) == 2 * model["n_layers"]
