"""L2 model correctness: manual backprop vs jax.grad, training dynamics,
update rule semantics, overflow accounting, dropout determinism."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import formats as F
from compile import model as M
from compile import quant

RNG = np.random.default_rng(7)


def init_params(m):
    params = []
    for s in m.param_specs():
        if s["init"] == "zeros":
            params.append(jnp.zeros(s["shape"], jnp.float32))
        else:
            lim = np.sqrt(6.0 / (s["fan_in"] + s["fan_out"]))
            params.append(
                jnp.asarray(RNG.uniform(-lim, lim, s["shape"]).astype(np.float32))
            )
    return params


def make_batch(m, batch):
    x = jnp.asarray(RNG.standard_normal((batch,) + m.input_shape).astype(np.float32))
    y = jnp.asarray(np.eye(10, dtype=np.float32)[RNG.integers(0, 10, batch)])
    return x, y


def run_step(m, step_fn, params, vels, x, y, lr=0.1, mom=0.0, maxnorm=0.0,
             seed=3.0, rates=None, steps=None, maxvs=None):
    G, L = m.n_groups, m.n_layers
    rates = jnp.zeros((L,), jnp.float32) if rates is None else rates
    steps = jnp.zeros((G,), jnp.float32) if steps is None else steps
    maxvs = jnp.zeros((G,), jnp.float32) if maxvs is None else maxvs
    args = (
        list(params) + list(vels)
        + [x, y, jnp.float32(lr), jnp.float32(mom), jnp.float32(maxnorm),
           jnp.float32(seed), rates, steps, maxvs]
    )
    out = step_fn(*args)
    n = len(params)
    return list(out[:n]), list(out[n : 2 * n]), out[2 * n], out[2 * n + 1]


# ---------------------------------------------------------------------------
# Manual backprop == jax.grad at float32 passthrough, no dropout.
# ---------------------------------------------------------------------------


def unquantized_loss(m, params, x, y):
    """Reference float32 forward: mode="off" uses no Pallas calls, so the
    whole graph is differentiable by jax.grad."""
    q = quant.Q(
        jnp.zeros((m.n_groups,), jnp.float32),
        jnp.zeros((m.n_groups,), jnp.float32),
        "off",
        m.n_layers,
    )
    split = m._split_params(list(params))
    rates = jnp.zeros((m.n_layers,), jnp.float32)
    (z, logp), _ = m._forward(q, split, x, False, jnp.float32(0.0), rates)
    return -jnp.sum(y * logp) / x.shape[0]


@pytest.mark.parametrize(
    "mk", [lambda: M.pi_mlp(units=32, k=2), lambda: M.conv(ch=(4, 4, 4)),
           lambda: M.conv32(ch=(4, 4, 4))],
    ids=["pi_mlp", "conv", "conv32"],
)
def test_manual_bwd_matches_jax_grad(mk):
    m = mk()
    params = init_params(m)
    x, y = make_batch(m, 16)
    step_fn = jax.jit(m.train_step("fixed"))
    vels = [jnp.zeros_like(p) for p in params]
    lr = 0.05
    new_params, _, loss, _ = run_step(m, step_fn, params, vels, x, y, lr=lr)

    gref = jax.grad(lambda ps: unquantized_loss(m, ps, x, y))(params)
    for p, p2, g, s in zip(params, new_params, gref, m.param_specs()):
        ours = (np.asarray(p) - np.asarray(p2)) / lr
        np.testing.assert_allclose(
            ours, np.asarray(g), atol=3e-5, rtol=1e-4,
            err_msg=f"grad mismatch for {s['name']}",
        )


# ---------------------------------------------------------------------------
# Training dynamics
# ---------------------------------------------------------------------------


def train_n(m, mode, n, steps_v=None, maxv_v=None, lr=0.1, mom=0.5):
    params = init_params(m)
    vels = [jnp.zeros_like(p) for p in params]
    x, y = make_batch(m, M.TRAIN_BATCH)
    step_fn = jax.jit(m.train_step(mode))
    loss = None
    for i in range(n):
        params, vels, loss, stats = run_step(
            m, step_fn, params, vels, x, y, lr=lr, mom=mom, seed=float(i),
            steps=steps_v, maxvs=maxv_v,
        )
    return float(loss), params, stats


def test_float32_loss_decreases():
    m = M.pi_mlp(units=32, k=2)
    l8, _, _ = train_n(m, "fixed", 8)
    assert l8 < 1.0, l8


def test_half_mode_trains():
    m = M.pi_mlp(units=32, k=2)
    l8, _, _ = train_n(m, "half", 8)
    assert l8 < 1.0, l8


def test_dynamic_12_10_bits_trains():
    """Paper headline config: 10-bit computations, 12-bit updates."""
    m = M.pi_mlp(units=32, k=2)
    G = m.n_groups
    steps_v = np.zeros(G, np.float32)
    maxv_v = np.zeros(G, np.float32)
    for l in range(m.n_layers):
        for k in range(F.N_KINDS):
            g = F.group_index(l, k)
            bits = 12 if k in F.UPDATE_KINDS else 10
            int_bits = 3 if k in (F.KIND_Z, F.KIND_H) else 0
            steps_v[g] = F.step_for(int_bits, bits)
            maxv_v[g] = F.maxv_for(int_bits)
    l12, params, stats = train_n(
        m, "fixed", 8, jnp.asarray(steps_v), jnp.asarray(maxv_v)
    )
    assert l12 < 1.5, l12
    # all parameters must sit on their storage grid
    for i, (p, s) in enumerate(zip(params, m.param_specs())):
        g = F.group_index(s["layer"], F.KIND_W if s["kind"] == "w" else F.KIND_B)
        k = np.asarray(p) / steps_v[g]
        np.testing.assert_allclose(k, np.round(k), atol=1e-5)


def test_severe_quantization_breaks_training():
    """Sanity: 4-bit everything must NOT train as well as float32 (the
    cliff the paper's figures 2-3 show must exist in our stack too)."""
    m = M.pi_mlp(units=32, k=2)
    G = m.n_groups
    steps_v = np.full(G, F.step_for(3, 4), np.float32)
    maxv_v = np.full(G, F.maxv_for(3), np.float32)
    l4, _, _ = train_n(m, "fixed", 8, jnp.asarray(steps_v), jnp.asarray(maxv_v))
    l32, _, _ = train_n(m, "fixed", 8)
    assert l4 > l32 + 0.2, (l4, l32)


# ---------------------------------------------------------------------------
# Update rule semantics
# ---------------------------------------------------------------------------


def test_max_norm_constraint_enforced():
    m = M.pi_mlp(units=16, k=2)
    params = [p * 50.0 if p.ndim >= 2 else p for p in init_params(m)]
    vels = [jnp.zeros_like(p) for p in params]
    x, y = make_batch(m, 16)
    step_fn = jax.jit(m.train_step("fixed"))
    c = 1.5
    new_params, _, _, _ = run_step(m, step_fn, params, vels, x, y, lr=0.0, maxnorm=c)
    w0 = np.asarray(new_params[0])  # [k, in, out]
    norms = np.sqrt((w0 ** 2).sum(axis=1))
    assert norms.max() <= c + 1e-4


def test_momentum_accumulates():
    m = M.pi_mlp(units=16, k=2)
    params = init_params(m)
    vels = [jnp.zeros_like(p) for p in params]
    x, y = make_batch(m, 16)
    step_fn = jax.jit(m.train_step("fixed"))
    _, vels1, _, _ = run_step(m, step_fn, params, vels, x, y, lr=0.1, mom=0.9)
    v_norm1 = sum(float(jnp.sum(v * v)) for v in vels1)
    assert v_norm1 > 0


def test_overflow_totals_account_every_site():
    m = M.pi_mlp(units=32, k=2)
    params = init_params(m)
    vels = [jnp.zeros_like(p) for p in params]
    x, y = make_batch(m, M.TRAIN_BATCH)
    step_fn = jax.jit(m.train_step("fixed"))
    G = m.n_groups
    steps_v = jnp.full((G,), F.step_for(4, 20), jnp.float32)
    maxv_v = jnp.full((G,), F.maxv_for(4), jnp.float32)
    _, _, _, stats = run_step(m, step_fn, params, vels, x, y,
                              steps=steps_v, maxvs=maxv_v)
    st = np.asarray(stats)
    B, U, k = M.TRAIN_BATCH, 32, 2
    # layer 0: z sees k*B*U weighted sums; h sees B*U outputs
    assert st[F.group_index(0, F.KIND_Z), 2] == k * B * U
    assert st[F.group_index(0, F.KIND_H), 2] == B * U
    # w group counts exactly the stored weight tensor (not the velocity)
    assert st[F.group_index(0, F.KIND_W), 2] == k * 784 * U
    # dz of layer 1 routes through k filters
    assert st[F.group_index(1, F.KIND_DZ), 2] == k * B * U


# ---------------------------------------------------------------------------
# Dropout
# ---------------------------------------------------------------------------


def test_dropout_deterministic_given_seed():
    x = jnp.asarray(RNG.standard_normal((64, 32)).astype(np.float32))
    a1, _ = quant.dropout(x, jnp.float32(0.5), jnp.float32(11.0), 0x10)
    a2, _ = quant.dropout(x, jnp.float32(0.5), jnp.float32(11.0), 0x10)
    a3, _ = quant.dropout(x, jnp.float32(0.5), jnp.float32(12.0), 0x10)
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))
    assert not np.array_equal(np.asarray(a1), np.asarray(a3))


def test_dropout_rate_zero_is_identity():
    x = jnp.asarray(RNG.standard_normal((16, 8)).astype(np.float32))
    y, _ = quant.dropout(x, jnp.float32(0.0), jnp.float32(5.0), 0x20)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


def test_dropout_rate_roughly_respected():
    x = jnp.ones((100, 100), jnp.float32)
    y, keep = quant.dropout(x, jnp.float32(0.5), jnp.float32(9.0), 0x30)
    frac = float(np.asarray(keep).mean())
    assert 0.45 < frac < 0.55, frac
    # inverted scaling: kept entries are 1/(1-p)
    kept_vals = np.asarray(y)[np.asarray(keep) > 0]
    np.testing.assert_allclose(kept_vals, 2.0, rtol=1e-5)
