"""Format bookkeeping invariants (paper Table 1 / section 4 semantics)."""

import numpy as np
from hypothesis import given, strategies as st

from compile import formats as F


def test_group_indexing_bijective():
    seen = set()
    for l in range(4):
        for k in range(F.N_KINDS):
            g = F.group_index(l, k)
            assert g not in seen
            seen.add(g)
    assert seen == set(range(F.n_groups(4)))


def test_group_names_match_order():
    names = [F.group_name(l, k) for l in range(3) for k in range(F.N_KINDS)]
    assert names[0] == "l0.w"
    assert names[F.group_index(1, F.KIND_DZ)] == "l1.dz"
    assert len(set(names)) == len(names)


@given(total_bits=st.integers(2, 32), int_bits=st.integers(-8, 10))
def test_grid_has_2_to_the_b_levels(total_bits, int_bits):
    """The representable grid must have exactly 2^B points in [-maxv, maxv)."""
    step = F.step_for(int_bits, total_bits)
    maxv = F.maxv_for(int_bits)
    n_levels = (maxv - (-maxv)) / step
    assert abs(n_levels - 2.0 ** total_bits) < 1e-6


def test_paper_fig1_radix_5_range():
    """Radix point after the 5th MSB -> range approximately [-32, 32]
    (paper section 9.2)."""
    assert F.maxv_for(5) == 32.0


def test_paper_headline_formats():
    """10-bit computations / 12-bit updates (paper abstract)."""
    comp = F.FixedFormat(total_bits=10, int_bits=3)
    up = F.FixedFormat(total_bits=12, int_bits=0)
    assert comp.step == 2.0 ** (3 - 9)
    assert up.step == 2.0 ** -11
    assert F.FLOAT32.step == 0.0


def test_half_float_table1_widths():
    """Table 1: half precision = 1 sign + 5 exponent + 10 mantissa bits."""
    f16 = np.float16
    info = np.finfo(f16)
    assert info.bits == 16
    assert info.nmant == 10
    assert info.iexp == 5
