"""L2 models: the paper's Maxout networks with low precision hooks.

Three topologies mirror the paper's experiments (scaled down; DESIGN.md
§Substitutions -- the paper itself notes that doubling the hidden layer
width does not change the minimum bit-widths, sections 9.2/9.3):

  pi_mlp -- permutation invariant MNIST model: two fully connected maxout
            layers + softmax (paper 8.1, first model).
  conv   -- three convolutional maxout stages + softmax over 28x28x1
            inputs (paper 8.1, second model).
  conv32 -- same shape over 32x32x3 inputs for the CIFAR10-like and
            SVHN-like datasets (paper 8.2/8.3).

Each model builds two compiled graphs per arithmetic mode:

  train_step: one full SGD+momentum step with EXPLICIT manual backprop and
              quantization at every signal the paper names (weights, bias,
              weighted sums, outputs + their gradients), the max-norm
              column constraint (Srebro & Shraibman 2005, used in paper
              8.1), and the parameter update quantized at the *update*
              bit-width (paper section 6).  Returns the per-group overflow
              counter matrix for the rust dynamic fixed point controller.
  eval_step:  forward only, no dropout; returns (error_count, loss_sum).

Everything that varies during training (learning rate, momentum, dropout
rates, max-norm bound, PRNG seed, per-group scales) is a runtime input:
the rust coordinator owns all schedules and the scaling-factor state.
"""

from __future__ import annotations

import jax.numpy as jnp

from . import formats as F
from . import quant
from .layers import ConvMaxout, DenseMaxout, DenseSoftmax, Flatten

TRAIN_BATCH = 64
EVAL_BATCH = 256
N_CLASSES = 10


class Model:
    """A stack of group-owning layers (+ optional Flatten) and its graphs."""

    def __init__(self, name: str, input_shape, layers, flatten_before_head=None):
        self.name = name
        self.input_shape = tuple(input_shape)  # per-example, e.g. (784,) or (28,28,1)
        self.layers = layers                    # group-owning layers, in order
        self.flatten = flatten_before_head      # Flatten between last conv and head
        self.n_layers = len(layers)
        self.n_groups = F.n_groups(self.n_layers)
        # Elementwise-quantize implementation for the standalone hooks:
        # "jnp" (XLA-fused, the CPU-artifact default) or "pallas" (the L1
        # kernel at every site — the TPU shape). See quant.Q docstring and
        # EXPERIMENTS.md §Perf. aot.py overrides via --elementwise.
        self.elementwise = "jnp"

    # -- parameter metadata ------------------------------------------------

    def param_specs(self):
        specs = []
        for i, layer in enumerate(self.layers):
            for s in layer.init_specs():
                s = dict(s)
                s["layer"] = i
                s["kind"] = "w" if s["name"].endswith(".w") else "b"
                specs.append(s)
        return specs

    def group_names(self):
        return [
            F.group_name(l, k) for l in range(self.n_layers) for k in range(F.N_KINDS)
        ]

    # -- forward/backward chains --------------------------------------------

    def _split_params(self, flat):
        """[w0, b0, w1, b1, ...] -> [(w0, b0), (w1, b1), ...]"""
        assert len(flat) == 2 * self.n_layers
        return [(flat[2 * i], flat[2 * i + 1]) for i in range(self.n_layers)]

    def _forward(self, q, params, x, train, seed, rates):
        """Returns (head_out, residuals list)."""
        resids = []
        h = x
        for i, layer in enumerate(self.layers[:-1]):
            h, r = layer.fwd(q, params[i], h, train, seed, rates)
            resids.append(r)
        if self.flatten is not None:
            h = self.flatten.fwd(h)
        head = self.layers[-1]
        out, r = head.fwd(q, params[-1], h, train, seed, rates)
        resids.append(r)
        return out, resids

    def _backward(self, q, params, resids, head_out, y, rates):
        """Returns (loss, grads list aligned with layers)."""
        head = self.layers[-1]
        loss, dz = head.loss_and_grad(q, head_out, y)
        grads = [None] * self.n_layers
        grads[-1], dx = head.bwd(q, params[-1], resids[-1], dz, True, rates)
        if self.flatten is not None:
            dx = self.flatten.bwd(dx)
        for i in range(self.n_layers - 2, -1, -1):
            layer = self.layers[i]
            g = q(dx, layer.layer, F.KIND_DH)
            need_dx = i > 0
            grads[i], dx = layer.bwd(q, params[i], resids[i], g, need_dx, rates)
        return loss, grads

    def _sgd_update(self, q, params, vels, grads, lr, mom, maxnorm):
        """Quantized SGD with momentum and max-norm column constraint.

        v' = Q_up(mom * v - lr * g)     (momentum buffer stored at the
                                         update bit-width; not counted in
                                         the group statistics)
        w' = Q_up(maxnorm(w + v'))      (parameter assignment -- the 'Up.'
                                         bit-width of paper section 6)
        """
        new_params, new_vels = [], []
        for i, layer in enumerate(self.layers):
            (w, b), (vw, vb), (gw, gb) = params[i], vels[i], grads[i]
            li = layer.layer

            vw2 = q(mom * vw - lr * gw, li, F.KIND_W, record=False)
            vb2 = q(mom * vb - lr * gb, li, F.KIND_B, record=False)

            w2 = w + vw2
            w2 = _max_norm(w2, maxnorm)
            w2 = q(w2, li, F.KIND_W)
            b2 = q(b + vb2, li, F.KIND_B)

            new_params.extend([w2, b2])
            new_vels.extend([vw2, vb2])
        return new_params, new_vels

    # -- compiled graph entry points -----------------------------------------

    def train_step(self, mode: str):
        """Build the train step callable for AOT lowering.

        Flat signature (all float32; see aot.py for the manifest):
          inputs : params..., vels..., x, y_onehot, lr, mom, maxnorm, seed,
                   rates[n_layers], steps[n_groups], maxvs[n_groups]
          outputs: params'..., vels'..., loss, overflow[n_groups, 3]
        """
        n_p = 2 * self.n_layers

        def step(*args):
            params_flat = list(args[:n_p])
            vels_flat = list(args[n_p : 2 * n_p])
            (x, y, lr, mom, maxnorm, seed, rates, steps, maxvs) = args[2 * n_p :]

            q = quant.Q(steps, maxvs, mode, self.n_layers, elementwise=self.elementwise)
            params = self._split_params(params_flat)
            vels = self._split_params(vels_flat)

            out, resids = self._forward(q, params, x, True, seed, rates)
            loss, grads = self._backward(q, params, resids, out, y, rates)
            new_params, new_vels = self._sgd_update(
                q, params, vels, grads, lr, mom, maxnorm
            )
            if mode == "half":
                # steps/maxvs are unused by the f16 round-trip graph; tie
                # them in with a zero-weight term so the lowered parameter
                # list is identical across modes (the MLIR->XLA conversion
                # prunes genuinely unused parameters).
                loss = loss + jnp.float32(0.0) * (jnp.sum(steps) + jnp.sum(maxvs))
            return tuple(new_params) + tuple(new_vels) + (loss, q.stats_matrix())

        return step

    def eval_step(self, mode: str):
        """Forward-only graph: inputs params..., x, y_onehot, steps, maxvs;
        outputs (error_count, loss_sum)."""
        n_p = 2 * self.n_layers

        def step(*args):
            params_flat = list(args[:n_p])
            x, y, steps, maxvs = args[n_p:]
            q = quant.Q(steps, maxvs, mode, self.n_layers, elementwise=self.elementwise)
            params = self._split_params(params_flat)
            rates = jnp.zeros((self.n_layers,), jnp.float32)
            (z, logp), _ = self._forward(q, params, x, False, jnp.float32(0.0), rates)
            batch = z.shape[0]
            loss_sum = -jnp.sum(y * logp)
            pred = jnp.argmax(z, axis=-1)
            truth = jnp.argmax(y, axis=-1)
            err = jnp.sum(jnp.where(pred != truth, 1.0, 0.0), dtype=jnp.float32)
            if mode == "half":
                # see train_step: keep the parameter list uniform.
                loss_sum = loss_sum + jnp.float32(0.0) * (jnp.sum(steps) + jnp.sum(maxvs))
            return err, loss_sum

        return step

    # -- example input shapes (for jit.lower) ---------------------------------

    def train_example_args(self):
        import jax

        f32 = jnp.float32
        sds = jax.ShapeDtypeStruct
        args = []
        for s in self.param_specs():
            args.append(sds(tuple(s["shape"]), f32))
        for s in self.param_specs():
            args.append(sds(tuple(s["shape"]), f32))
        args.append(sds((TRAIN_BATCH,) + self.input_shape, f32))       # x
        args.append(sds((TRAIN_BATCH, N_CLASSES), f32))                # y
        for _ in range(4):                                             # lr mom maxnorm seed
            args.append(sds((), f32))
        args.append(sds((self.n_layers,), f32))                        # rates
        args.append(sds((self.n_groups,), f32))                        # steps
        args.append(sds((self.n_groups,), f32))                        # maxvs
        return args

    def eval_example_args(self):
        import jax

        f32 = jnp.float32
        sds = jax.ShapeDtypeStruct
        args = [sds(tuple(s["shape"]), f32) for s in self.param_specs()]
        args.append(sds((EVAL_BATCH,) + self.input_shape, f32))
        args.append(sds((EVAL_BATCH, N_CLASSES), f32))
        args.append(sds((self.n_groups,), f32))
        args.append(sds((self.n_groups,), f32))
        return args


def _max_norm(w, c):
    """Scale columns (incoming weight vectors) to norm <= c; c <= 0 disables.

    Norm is over the fan-in axes: all but the last axis for dense [in, out]
    and maxout [k, in, out] -> per (k, out); (kh, kw, cin) for conv HWIO.
    """
    axes = tuple(range(w.ndim - 1)) if w.ndim != 3 else (1,)
    norm = jnp.sqrt(jnp.sum(w * w, axis=axes, keepdims=True))
    scale = jnp.minimum(jnp.float32(1.0), c / jnp.maximum(norm, jnp.float32(1e-7)))
    return jnp.where(c > 0, w * scale, w)


# ---------------------------------------------------------------------------
# Model zoo
# ---------------------------------------------------------------------------


def pi_mlp(units: int = 128, k: int = 4, name: str = "pi_mlp") -> Model:
    """Permutation invariant maxout MLP (paper 8.1; Goodfellow 240xk5 x2)."""
    return Model(
        name,
        (784,),
        [
            DenseMaxout(0, 784, units, k, dropout_salt=0x10),
            DenseMaxout(1, units, units, k, dropout_salt=0x20),
            DenseSoftmax(2, units, N_CLASSES, dropout_salt=0x30),
        ],
    )


def conv(ch=(8, 16, 16), k: int = 2) -> Model:
    """Conv maxout net over 28x28x1 (paper 8.1, convolutional model)."""
    c0, c1, c2 = ch
    flat = 3 * 3 * c2
    return Model(
        "conv",
        (28, 28, 1),
        [
            ConvMaxout(0, 28, 1, c0, k, 5, 2, dropout_salt=0x10),
            ConvMaxout(1, 14, c0, c1, k, 5, 2, dropout_salt=0x20),
            ConvMaxout(2, 7, c1, c2, k, 5, 2, dropout_salt=0x30),
            DenseSoftmax(3, flat, N_CLASSES, dropout_salt=0x40),
        ],
        flatten_before_head=Flatten((3, 3, c2)),
    )


def conv32(ch=(16, 16, 24), k: int = 2) -> Model:
    """Conv maxout net over 32x32x3 (paper 8.2 CIFAR10 / 8.3 SVHN models)."""
    c0, c1, c2 = ch
    flat = 4 * 4 * c2
    return Model(
        "conv32",
        (32, 32, 3),
        [
            ConvMaxout(0, 32, 3, c0, k, 5, 2, dropout_salt=0x10),
            ConvMaxout(1, 16, c0, c1, k, 5, 2, dropout_salt=0x20),
            ConvMaxout(2, 8, c1, c2, k, 5, 2, dropout_salt=0x30),
            DenseSoftmax(3, flat, N_CLASSES, dropout_salt=0x40),
        ],
        flatten_before_head=Flatten((4, 4, c2)),
    )


def pi_mlp_wide() -> Model:
    """Double-width pi_mlp for the paper's 'doubling the number of hidden
    units does not allow any further reduction of the bit-widths'
    ablation (sections 9.2/9.3)."""
    return pi_mlp(units=256, name="pi_mlp_wide")


MODELS = {"pi_mlp": pi_mlp, "conv": conv, "conv32": conv32, "pi_mlp_wide": pi_mlp_wide}
