"""Numeric-format bookkeeping shared by the L2 model and the AOT manifest.

The paper (Courbariaux, David & Bengio 2014) quantizes eight signal kinds per
layer -- weights, biases, weighted sums, outputs, and the gradients of each --
and gives every (layer, kind) pair its own scaling factor in dynamic fixed
point mode.  This module defines the canonical group indexing used across the
whole stack:

  group(layer, kind) = layer * N_KINDS + kind

The rust coordinator (`lpdnn::coordinator::scale_ctrl`) and the golden model
(`lpdnn::golden`) rely on the exact same mapping, which is exported to
`artifacts/manifest.json` by `aot.py`.

A fixed point format is described by two runtime scalars per group:

  step = 2**(int_bits - (total_bits - 1))   -- quantization step (LSB value)
  maxv = 2**int_bits                        -- saturation magnitude

so the representable grid is { k * step : -maxv/step <= k <= maxv/step - 1 },
i.e. a `total_bits`-bit signed mantissa with the radix point after the
`int_bits`-th most significant magnitude bit (paper Fig. 1 terminology).
`step == 0` is the float32 passthrough sentinel.
"""

from __future__ import annotations

import dataclasses

# Signal kinds, one scaling-factor group each (paper section 5).
KIND_W = 0   # weights (parameter storage -> update bit-width)
KIND_B = 1   # biases  (parameter storage -> update bit-width)
KIND_Z = 2   # weighted sums, pre-nonlinearity (computation bit-width)
KIND_H = 3   # outputs, post-nonlinearity     (computation bit-width)
KIND_DW = 4  # gradient wrt weights           (computation bit-width)
KIND_DB = 5  # gradient wrt biases            (computation bit-width)
KIND_DZ = 6  # gradient wrt weighted sums     (computation bit-width)
KIND_DH = 7  # gradient wrt outputs           (computation bit-width)
N_KINDS = 8

KIND_NAMES = ["w", "b", "z", "h", "dw", "db", "dz", "dh"]

# Kinds quantized with the *parameter update* bit-width; the rest use the
# *computation* bit-width (paper section 6, "two different bit widths").
UPDATE_KINDS = (KIND_W, KIND_B)


def group_index(layer: int, kind: int) -> int:
    """Flat scaling-factor group index for (layer, kind)."""
    assert 0 <= kind < N_KINDS
    return layer * N_KINDS + kind


def n_groups(n_layers: int) -> int:
    return n_layers * N_KINDS


def group_name(layer: int, kind: int) -> str:
    return f"l{layer}.{KIND_NAMES[kind]}"


def step_for(int_bits: int, total_bits: int) -> float:
    """LSB value of a `total_bits`-wide format with `int_bits` integer bits."""
    return float(2.0 ** (int_bits - (total_bits - 1)))


def maxv_for(int_bits: int) -> float:
    """Saturation magnitude of a format with `int_bits` integer bits."""
    return float(2.0 ** int_bits)


@dataclasses.dataclass(frozen=True)
class FixedFormat:
    """A concrete fixed point format: total width (incl. sign) + radix."""

    total_bits: int
    int_bits: int

    @property
    def step(self) -> float:
        if self.total_bits == 0:  # float32 passthrough sentinel
            return 0.0
        return step_for(self.int_bits, self.total_bits)

    @property
    def maxv(self) -> float:
        if self.total_bits == 0:
            return 0.0
        return maxv_for(self.int_bits)


# Passthrough sentinel (float32 simulation): step == 0 disables quantization.
FLOAT32 = FixedFormat(total_bits=0, int_bits=0)
