"""L1 Pallas kernel: fused quantize + overflow-statistics.

This is the elementwise hot-spot of the paper's simulation contract
(section 7): every time an activation, gradient or parameter is *stored*,
its precision is artificially reduced; compute (the accumulators) stays
float32.  On TPU this fusion is exactly the right shape: the value is
quantized in-register between the compute and the single store to HBM, and
the two overflow counters the dynamic fixed point controller needs
(paper section 5) are reduced on the fly instead of in a second pass over
the tensor.

Kernel contract (mirrors kernels.ref.quantize_with_stats_ref):

  y      = clip(round_half_away(x/step), -maxv/step, maxv/step-1) * step
  counts = [ #{|x| >= maxv}, #{|x| >= maxv/2} ]       (float32 exact counts)
  step == 0  ->  passthrough, counts = 0.

The kernel is written against a 1-D view of the input, tiled into VMEM-sized
blocks; the counters live in a single (1, 2) output block revisited by every
grid step (sequential TPU grid -> safe accumulation).  `interpret=True`
everywhere: the CPU PJRT plugin cannot execute Mosaic custom-calls, so the
kernel lowers to plain HLO (see DESIGN.md §Hardware-Adaptation).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default 1-D tile: 8 * 1024 f32 = 32 KiB per block, comfortably inside VMEM
# alongside the output block and counters (see EXPERIMENTS.md §Perf for the
# footprint table).
DEFAULT_BLOCK = 8 * 1024


def _quantize_block(x, step, maxv):
    """Quantize one block; `step`/`maxv` are f32 scalars (step>0 guarded)."""
    safe = jnp.where(step > 0, step, jnp.float32(1.0))
    lim_lo = -maxv / safe
    lim_hi = maxv / safe - 1.0
    scaled = x / safe
    rounded = jnp.sign(scaled) * jnp.floor(jnp.abs(scaled) + 0.5)
    q = jnp.clip(rounded, lim_lo, lim_hi) * safe
    return jnp.where(step > 0, q, x)


def _kernel(scale_ref, x_ref, y_ref, cnt_ref):
    """One grid step: quantize a (1, block) tile and accumulate counters."""
    step = scale_ref[0, 0]
    maxv = scale_ref[0, 1]
    x = x_ref[...]

    y_ref[...] = _quantize_block(x, step, maxv)

    absx = jnp.abs(x)
    live = jnp.where(step > 0, jnp.float32(1.0), jnp.float32(0.0))
    n_over = jnp.sum(jnp.where(absx >= maxv, 1.0, 0.0)) * live
    n_half = jnp.sum(jnp.where(absx >= maxv * 0.5, 1.0, 0.0)) * live

    @pl.when(pl.program_id(0) == 0)
    def _init():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    cnt_ref[0, 0] += n_over
    cnt_ref[0, 1] += n_half


@functools.partial(jax.jit, static_argnames=("block",))
def quantize_with_stats(x, step, maxv, block: int = DEFAULT_BLOCK):
    """Quantize `x` (any shape) and report overflow statistics.

    Returns (y, stats) with y.shape == x.shape and stats == f32[3]
    (n_over, n_half, n_total).  `step` and `maxv` are runtime f32 scalars:
    one compiled artifact serves float32 (step=0), any fixed point format
    and any dynamic fixed point schedule (see DESIGN.md).
    """
    orig_shape = x.shape
    n = x.size
    x1 = jnp.reshape(jnp.asarray(x, jnp.float32), (n,))

    # Pad to a whole number of blocks; padded zeros never count as overflow
    # (maxv > 0 whenever counting is live).
    bl = min(block, max(n, 1))
    n_pad = (-n) % bl
    if n_pad:
        x1 = jnp.concatenate([x1, jnp.zeros((n_pad,), jnp.float32)])
    n_blocks = x1.size // bl
    x2 = x1.reshape(n_blocks, bl)

    scale = jnp.stack([jnp.float32(step), jnp.float32(maxv)]).reshape(1, 2)

    y2, cnt = pl.pallas_call(
        _kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((1, 2), lambda i: (0, 0)),       # (step, maxv)
            pl.BlockSpec((1, bl), lambda i: (i, 0)),      # x tile
        ],
        out_specs=[
            pl.BlockSpec((1, bl), lambda i: (i, 0)),      # y tile
            pl.BlockSpec((1, 2), lambda i: (0, 0)),       # counters (revisited)
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_blocks, bl), jnp.float32),
            jax.ShapeDtypeStruct((1, 2), jnp.float32),
        ],
        interpret=True,
    )(scale, x2)

    y = y2.reshape(-1)[:n].reshape(orig_shape)
    stats = jnp.stack([cnt[0, 0], cnt[0, 1], jnp.float32(n)])
    return y, stats


def quantize(x, step, maxv, block: int = DEFAULT_BLOCK):
    """Quantize only (statistics discarded)."""
    y, _ = quantize_with_stats(x, step, maxv, block=block)
    return y
