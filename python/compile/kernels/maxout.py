"""L1 Pallas kernel: fused maxout dense layer forward.

The compute hot-spot of the paper's networks is the maxout unit
(section 2): k dot products per output unit, a bias add, a max over the k
filters -- and, in the low precision simulation, a quantization of every
weighted sum z_j = w_j . x + b_j *before* the max (the weighted sums form
their own scaling-factor group, distinct from the post-nonlinearity
outputs).

GPU implementations do this as k cuBLAS GEMMs + an elementwise max over
materialized [k, B, U] tensors.  The TPU-shaped rethink (DESIGN.md
§Hardware-Adaptation): tile (batch x units) into MXU-sized blocks, keep a
float32 accumulator of shape [k, bt, ut] in VMEM scratch across the
reduction (d_in) grid dimension, and on the last reduction step apply
bias + quantize + max + argmax in-register, storing only the [bt, ut]
result -- the [k, B, U] intermediate never exists in HBM, and the wide
accumulator narrows to the low precision grid exactly once, at the store,
matching the paper's "wide accumulator, narrow storage" hypothesis
(section 7).

Outputs:
  h      f32[B, U]   = max_j quantize(z_j)
  amax   f32[B, U]   = argmax_j quantize(z_j)  (filter routing for backprop)
  counts f32[1, 2]   = [#{|z| >= maxv}, #{|z| >= maxv/2}] over all k filters

interpret=True (CPU PJRT cannot run Mosaic custom-calls); block shapes are
still chosen as if targeting the 128x128 MXU so the §Perf VMEM/MXU estimate
is meaningful.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .quantize import _quantize_block


def _pick_block(dim: int, preferred: int) -> int:
    """Largest divisor of `dim` that is <= preferred (block shapes must tile
    the array exactly; interpret-mode padding semantics are undefined)."""
    for cand in range(min(preferred, dim), 0, -1):
        if dim % cand == 0:
            return cand
    return dim


def _kernel(k: int, scale_ref, x_ref, w_ref, b_ref, h_ref, amax_ref, cnt_ref, acc_ref):
    ni = pl.num_programs(2)
    i = pl.program_id(2)
    # program_id must be read at kernel top level (not inside a pl.when body).
    first_tile = jnp.logical_and(pl.program_id(0) == 0, pl.program_id(1) == 0)

    @pl.when(i == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0]  # [bt, it]
    # k is small and static: unroll the filter loop; each iteration is one
    # MXU matmul accumulating into VMEM scratch.
    for j in range(k):
        acc_ref[j] += jnp.dot(x, w_ref[j], preferred_element_type=jnp.float32)

    @pl.when(i == ni - 1)
    def _finish():
        step = scale_ref[0, 0]
        maxv = scale_ref[0, 1]
        z = acc_ref[...] + b_ref[...][:, None, :]          # [k, bt, ut]
        zq = _quantize_block(z, step, maxv)
        h_ref[0] = jnp.max(zq, axis=0)
        amax_ref[0] = jnp.argmax(zq, axis=0).astype(jnp.float32)

        absz = jnp.abs(z)
        live = jnp.where(step > 0, jnp.float32(1.0), jnp.float32(0.0))
        n_over = jnp.sum(jnp.where(absz >= maxv, 1.0, 0.0)) * live
        n_half = jnp.sum(jnp.where(absz >= maxv * 0.5, 1.0, 0.0)) * live

        @pl.when(first_tile)
        def _init_cnt():
            cnt_ref[...] = jnp.zeros_like(cnt_ref)

        cnt_ref[0, 0] += n_over
        cnt_ref[0, 1] += n_half


@functools.partial(jax.jit, static_argnames=("bt", "ut", "it"))
def maxout_dense(x, w, b, step_z, maxv_z, bt: int = 64, ut: int = 128, it: int = 1024):
    # Default block preferences: (bt, ut) MXU-aligned; `it` covers the whole
    # reduction for the paper's layer sizes (d_in <= 1024 ==> w block
    # k*it*ut*4B <= 2 MiB, comfortably inside the ~16 MiB VMEM budget with
    # the k*bt*ut accumulator), so the grid has a single reduction step.
    # EXPERIMENTS.md §Perf logs the interpret-mode effect of this choice.
    """Fused maxout dense forward.

    x: f32[B, I]; w: f32[k, I, U]; b: f32[k, U];
    step_z/maxv_z: runtime f32 scalars for the weighted-sum group.

    Returns (h f32[B, U], amax f32[B, U], stats f32[3]).
    Block sizes are preferences; the actual block is the largest divisor of
    each dimension not exceeding the preference (MXU-aligned when possible).
    """
    B, I = x.shape
    k, I2, U = w.shape
    assert I == I2 and b.shape == (k, U), (x.shape, w.shape, b.shape)

    bt = _pick_block(B, bt)
    ut = _pick_block(U, ut)
    it = _pick_block(I, it)
    grid = (B // bt, U // ut, I // it)

    scale = jnp.stack([jnp.float32(step_z), jnp.float32(maxv_z)]).reshape(1, 2)
    # Batch dim gets a leading unit axis so every operand block is rank>=2.
    x3 = x.reshape(1, B, I)

    kernel = functools.partial(_kernel, k)
    h, amax, cnt = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 2), lambda ib, iu, ii: (0, 0)),          # scale
            pl.BlockSpec((1, bt, it), lambda ib, iu, ii: (0, ib, ii)),  # x
            pl.BlockSpec((k, it, ut), lambda ib, iu, ii: (0, ii, iu)),  # w
            pl.BlockSpec((k, ut), lambda ib, iu, ii: (0, iu)),          # b
        ],
        out_specs=[
            pl.BlockSpec((1, bt, ut), lambda ib, iu, ii: (0, ib, iu)),  # h
            pl.BlockSpec((1, bt, ut), lambda ib, iu, ii: (0, ib, iu)),  # amax
            pl.BlockSpec((1, 2), lambda ib, iu, ii: (0, 0)),            # counts
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, B, U), jnp.float32),
            jax.ShapeDtypeStruct((1, B, U), jnp.float32),
            jax.ShapeDtypeStruct((1, 2), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((k, bt, ut), jnp.float32)],
        interpret=True,
    )(scale, x3, w, b)

    stats = jnp.stack([cnt[0, 0], cnt[0, 1], jnp.float32(k * B * U)])
    return h[0], amax[0], stats
