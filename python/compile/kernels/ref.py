"""Pure-jnp oracles for the Pallas kernels.

Everything in this file is straight-line jax.numpy with no Pallas, no custom
control flow and no cleverness: it is the correctness contract that
`quantize.py` and `maxout.py` are tested against (pytest + hypothesis), and
its semantics are mirrored bit-for-bit by the rust golden quantizer
(`lpdnn::arith::Quantizer`).

Quantization semantics (see formats.py for the (step, maxv) encoding):

  q(x)    = clip(round_half_away(x / step), -maxv/step, maxv/step - 1) * step
  q(x)    = x                                     when step == 0 (float32)

Overflow counters (per call, i.e. per scaling-factor group per step):

  n_over  = #{ |x| >= maxv }        -- would saturate at the current scale
  n_half  = #{ |x| >= maxv / 2 }    -- would saturate at half the scale
  n_total = x.size

The dynamic fixed point controller (paper section 5) grows the scale when
n_over/n_total exceeds the max overflow rate and shrinks it when
n_half/n_total stays below it.
"""

from __future__ import annotations

import jax.numpy as jnp


def round_half_away(x):
    """Round to nearest, ties away from zero (classic fixed-point rounding)."""
    return jnp.sign(x) * jnp.floor(jnp.abs(x) + 0.5)


def quantize_ref(x, step, maxv):
    """Quantize `x` onto the fixed point grid described by (step, maxv).

    `step` and `maxv` are scalars (python floats or 0-d arrays).  A `step`
    of zero is the float32 passthrough sentinel.
    """
    x = jnp.asarray(x, jnp.float32)
    step = jnp.float32(step)
    maxv = jnp.float32(maxv)
    safe = jnp.where(step > 0, step, jnp.float32(1.0))
    lim_lo = -maxv / safe
    lim_hi = maxv / safe - 1.0
    q = jnp.clip(round_half_away(x / safe), lim_lo, lim_hi) * safe
    return jnp.where(step > 0, q, x)


def overflow_stats_ref(x, maxv):
    """(n_over, n_half, n_total) as float32 scalars (counts fit exactly)."""
    x = jnp.asarray(x, jnp.float32)
    absx = jnp.abs(x)
    n_over = jnp.sum(jnp.where(absx >= maxv, 1.0, 0.0), dtype=jnp.float32)
    n_half = jnp.sum(jnp.where(absx >= maxv * 0.5, 1.0, 0.0), dtype=jnp.float32)
    n_total = jnp.float32(x.size)
    return jnp.stack([n_over, n_half, n_total])


def quantize_with_stats_ref(x, step, maxv):
    """Reference for the fused quantize + overflow-counter kernel.

    When step == 0 the value passes through and the over/half counters are
    zero (there is no scale to overflow), but n_total is still reported.
    """
    y = quantize_ref(x, step, maxv)
    stats = overflow_stats_ref(x, maxv)
    live = jnp.where(jnp.float32(step) > 0, jnp.float32(1.0), jnp.float32(0.0))
    mask = jnp.stack([live, live, jnp.float32(1.0)])
    return y, stats * mask


def maxout_dense_ref(x, w, b, step_z, maxv_z):
    """Reference maxout dense layer forward.

    x: [batch, d_in]; w: [k, d_in, d_out]; b: [k, d_out].
    Per filter j: z_j = x @ w[j] + b[j], quantized as the layer's weighted-sum
    group; output h = max_j z_q_j (paper section 2).  Returns (h, z_stats)
    where z_stats counts overflow over all k*batch*d_out weighted sums.
    """
    x = jnp.asarray(x, jnp.float32)
    z = jnp.einsum("bi,kio->kbo", x, w) + b[:, None, :]
    zq, stats = quantize_with_stats_ref(z, step_z, maxv_z)
    return jnp.max(zq, axis=0), stats


def half_roundtrip_ref(x):
    """Float16 simulation: round-trip through IEEE half precision."""
    return jnp.asarray(x, jnp.float32).astype(jnp.float16).astype(jnp.float32)
