"""L2 layers: maxout dense, softmax head, conv-maxout stage.

Backward passes are written EXPLICITLY (not jax.grad of the quantized
forward): quantization is a staircase whose a.e. derivative is zero, so the
paper's scheme -- quantize the *signals* (dh, dz, dw, db) while propagating
straight-through across each quantizer -- must be coded by hand.  For the
maxout dense layer the backward is exact manual backprop (gradient routing
through the argmax filter recorded by the fused forward kernel).  For conv
stages the *linear/piecewise-linear local ops* (conv, bias, max, pool) are
differentiated with jax.vjp at the quantized operands, and quantization
hooks are applied between them -- identical semantics, far less code.

Every layer exposes:
  init_specs()            -> parameter metadata for the rust initializer
  fwd(q, params, x, train, seed, rates) -> (out, residuals)
  bwd(q, params, residuals, g_out, need_dx) -> (dparams, dx or None)

Group convention (formats.py): per layer, W/B hold parameter storage
(update bit-width), Z/H the forward signals, DW/DB/DZ/DH the gradients
(computation bit-width).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import formats as F
from . import quant
from .kernels.maxout import maxout_dense
from .kernels import ref


class DenseMaxout:
    """Fully connected maxout layer (paper section 2): k filters per unit."""

    def __init__(self, layer: int, d_in: int, d_out: int, k: int, dropout_salt: int):
        self.layer = layer
        self.d_in = d_in
        self.d_out = d_out
        self.k = k
        self.salt = dropout_salt

    def init_specs(self):
        return [
            {
                "name": f"l{self.layer}.w",
                "shape": [self.k, self.d_in, self.d_out],
                "init": "glorot_uniform",
                "fan_in": self.d_in,
                "fan_out": self.d_out,
            },
            {
                "name": f"l{self.layer}.b",
                "shape": [self.k, self.d_out],
                "init": "zeros",
            },
        ]

    def fwd(self, q: quant.Q, params, x, train: bool, seed, rates):
        w, b = params
        if train:
            xd, keep = quant.dropout(x, rates[self.layer], seed, self.salt)
        else:
            xd, keep = x, None

        if q.mode in ("half", "off"):
            # Fused kernel quantizes on the fixed point grid only; in half
            # (f16 round-trip) and off (pure float32 reference) modes run
            # the reference einsum instead.
            z = jnp.einsum("bi,kio->kbo", xd, w) + b[:, None, :]
            zq = q(z, self.layer, F.KIND_Z)
            amax = jnp.argmax(zq, axis=0).astype(jnp.float32)
            h_pre = jnp.max(zq, axis=0)
        else:
            step_z, maxv_z = q.scale(self.layer, F.KIND_Z)
            h_pre, amax, z_stats = maxout_dense(xd, w, b, step_z, maxv_z)
            q.record(self.layer, F.KIND_Z, z_stats)

        h = q(h_pre, self.layer, F.KIND_H)
        return h, (xd, keep, amax)

    def bwd(self, q: quant.Q, params, residuals, g, need_dx: bool, rates):
        w, _b = params
        xd, keep, amax = residuals
        # Straight-through across the output quantizer; route the gradient
        # to the winning filter (exact subgradient of max over quantized z).
        sel = jnp.stack(
            [jnp.where(amax == j, 1.0, 0.0) for j in range(self.k)]
        )  # [k, B, U]
        dz = q(sel * g[None, :, :], self.layer, F.KIND_DZ)

        dw = q(jnp.einsum("bi,kbo->kio", xd, dz), self.layer, F.KIND_DW)
        db = q(jnp.sum(dz, axis=1), self.layer, F.KIND_DB)

        dx = None
        if need_dx:
            dxd = jnp.einsum("kbo,kio->bi", dz, w)
            dx = quant.dropout_bwd(dxd, keep, rates[self.layer]) if keep is not None else dxd
        return (dw, db), dx


class DenseSoftmax:
    """Final densely connected softmax layer (k = 1, no nonlinearity)."""

    def __init__(self, layer: int, d_in: int, n_classes: int, dropout_salt: int):
        self.layer = layer
        self.d_in = d_in
        self.n_classes = n_classes
        self.salt = dropout_salt

    def init_specs(self):
        return [
            {
                "name": f"l{self.layer}.w",
                "shape": [self.d_in, self.n_classes],
                "init": "glorot_uniform",
                "fan_in": self.d_in,
                "fan_out": self.n_classes,
            },
            {
                "name": f"l{self.layer}.b",
                "shape": [self.n_classes],
                "init": "zeros",
            },
        ]

    def fwd(self, q: quant.Q, params, x, train: bool, seed, rates):
        w, b = params
        if train:
            xd, keep = quant.dropout(x, rates[self.layer], seed, self.salt)
        else:
            xd, keep = x, None
        z = q(xd @ w + b, self.layer, F.KIND_Z)
        # Softmax + cross-entropy stay float32: the paper's simulation keeps
        # accumulators and the loss at full precision (section 7).
        logp = jax.nn.log_softmax(z, axis=-1)
        return (z, logp), (xd, keep, z)

    def loss_and_grad(self, q: quant.Q, fwd_out, y_onehot):
        """Cross-entropy loss and the quantized dz = (p - y)/B."""
        z, logp = fwd_out
        batch = z.shape[0]
        loss = -jnp.sum(y_onehot * logp) / batch
        p = jnp.exp(logp)
        dz = q((p - y_onehot) / batch, self.layer, F.KIND_DZ)
        return loss, dz

    def bwd(self, q: quant.Q, params, residuals, dz, need_dx: bool, rates):
        w, _b = params
        xd, keep, _z = residuals
        dw = q(xd.T @ dz, self.layer, F.KIND_DW)
        db = q(jnp.sum(dz, axis=0), self.layer, F.KIND_DB)
        dx = None
        if need_dx:
            dxd = dz @ w.T
            dx = quant.dropout_bwd(dxd, keep, rates[self.layer]) if keep is not None else dxd
        return (dw, db), dx


class ConvMaxout:
    """Convolutional maxout stage: conv -> +b -> quantize z -> max over k
    filter groups -> spatial max pool -> quantize h (paper sections 8.1-8.3).

    Input/output layout NHWC.  Local linear/piecewise-linear maps are
    differentiated with jax.vjp at the quantized operands (exact for conv /
    bias / max / pool), with quantization hooks applied between them.
    """

    def __init__(
        self,
        layer: int,
        hw: int,
        c_in: int,
        c_out: int,
        k: int,
        ksize: int,
        pool: int,
        dropout_salt: int,
    ):
        self.layer = layer
        self.hw = hw
        self.c_in = c_in
        self.c_out = c_out
        self.k = k
        self.ksize = ksize
        self.pool = pool
        self.salt = dropout_salt
        self.out_hw = hw // pool  # SAME conv, then pool

    def init_specs(self):
        fan_in = self.ksize * self.ksize * self.c_in
        fan_out = self.ksize * self.ksize * self.c_out
        return [
            {
                "name": f"l{self.layer}.w",
                "shape": [self.ksize, self.ksize, self.c_in, self.k * self.c_out],
                "init": "glorot_uniform",
                "fan_in": fan_in,
                "fan_out": fan_out,
            },
            {
                "name": f"l{self.layer}.b",
                "shape": [self.k * self.c_out],
                "init": "zeros",
            },
        ]

    def _conv(self, x, w):
        return jax.lax.conv_general_dilated(
            x,
            w,
            window_strides=(1, 1),
            padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )

    def _max_pool(self, zq):
        """max over k filter groups then spatial max pool (both piecewise
        linear, differentiated together with one vjp in bwd)."""
        b, h, w_, _ = zq.shape
        z5 = zq.reshape(b, h, w_, self.k, self.c_out)
        m = jnp.max(z5, axis=3)
        return jax.lax.reduce_window(
            m,
            -jnp.inf,
            jax.lax.max,
            (1, self.pool, self.pool, 1),
            (1, self.pool, self.pool, 1),
            "VALID",
        )

    def fwd(self, q: quant.Q, params, x, train: bool, seed, rates):
        w, b = params
        if train:
            xd, keep = quant.dropout(x, rates[self.layer], seed, self.salt)
        else:
            xd, keep = x, None
        z = self._conv(xd, w) + b
        zq = q(z, self.layer, F.KIND_Z)
        hp = self._max_pool(zq)
        h = q(hp, self.layer, F.KIND_H)
        return h, (xd, keep, zq)

    def bwd(self, q: quant.Q, params, residuals, g, need_dx: bool, rates):
        w, _b = params
        xd, keep, zq = residuals

        # Through max-over-filters + pool (exact subgradient at zq).
        _, pool_vjp = jax.vjp(self._max_pool, zq)
        dz = q(pool_vjp(g)[0], self.layer, F.KIND_DZ)

        # Through conv at the quantized input.
        _, conv_vjp = jax.vjp(lambda xx, ww: self._conv(xx, ww), xd, w)
        dxd, dw = conv_vjp(dz)
        dw = q(dw, self.layer, F.KIND_DW)
        db = q(jnp.sum(dz, axis=(0, 1, 2)), self.layer, F.KIND_DB)

        dx = None
        if need_dx:
            dx = quant.dropout_bwd(dxd, keep, rates[self.layer]) if keep is not None else dxd
        return (dw, db), dx


class Flatten:
    """Shape adapter between conv stages and the dense softmax head.

    Not a parameterised layer: it owns no groups and no dropout.
    """

    def __init__(self, shape_in):
        self.shape_in = tuple(shape_in)

    def fwd(self, x):
        return x.reshape(x.shape[0], -1)

    def bwd(self, g):
        return g.reshape((g.shape[0],) + self.shape_in)
