"""AOT lowering: jax graphs -> HLO text artifacts + manifest.json.

Interchange format is HLO *text*, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the `xla` crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Run as:  cd python && python -m compile.aot --out-dir ../artifacts

Python runs ONLY here, at `make artifacts` time.  The rust binary is
self-contained once `artifacts/` exists: it reads manifest.json for every
shape, parameter spec, group table and input/output ordering, so nothing
about the model topology is duplicated on the rust side.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from . import model as M

MODES = ("fixed", "half")


def to_hlo_text(lowered) -> str:
    """Lower a jitted function to XLA HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def train_io_names(m: M.Model):
    specs = m.param_specs()
    inputs = [s["name"] for s in specs]
    inputs += [f"vel:{s['name']}" for s in specs]
    inputs += ["x", "y", "lr", "mom", "maxnorm", "seed", "rates", "steps", "maxvs"]
    outputs = [s["name"] for s in specs]
    outputs += [f"vel:{s['name']}" for s in specs]
    outputs += ["loss", "overflow"]
    return inputs, outputs


def eval_io_names(m: M.Model):
    specs = m.param_specs()
    inputs = [s["name"] for s in specs] + ["x", "y", "steps", "maxvs"]
    outputs = ["err_count", "loss_sum"]
    return inputs, outputs


def layer_descr(m: M.Model):
    out = []
    for layer in m.layers:
        d = {"layer": layer.layer, "type": type(layer).__name__}
        for attr in ("d_in", "d_out", "k", "hw", "c_in", "c_out", "ksize", "pool", "n_classes"):
            if hasattr(layer, attr):
                d[attr] = getattr(layer, attr)
        out.append(d)
    return out


def build_model_entry(m: M.Model):
    return {
        "name": m.name,
        "input_shape": list(m.input_shape),
        "n_layers": m.n_layers,
        "n_groups": m.n_groups,
        "group_names": m.group_names(),
        "train_batch": M.TRAIN_BATCH,
        "eval_batch": M.EVAL_BATCH,
        "n_classes": M.N_CLASSES,
        "params": m.param_specs(),
        "layers": layer_descr(m),
    }


def lower_artifact(m: M.Model, mode: str, graph: str, out_dir: str, manifest: dict):
    key = f"{m.name}_{mode}_{graph}"
    fname = f"{key}.hlo.txt"
    path = os.path.join(out_dir, fname)

    if graph == "train":
        fn, example = m.train_step(mode), m.train_example_args()
        inputs, outputs = train_io_names(m)
    else:
        fn, example = m.eval_step(mode), m.eval_example_args()
        inputs, outputs = eval_io_names(m)

    print(f"  lowering {key} ...", flush=True)
    lowered = jax.jit(fn).lower(*example)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)

    manifest["artifacts"][key] = {
        "file": fname,
        "model": m.name,
        "mode": mode,
        "graph": graph,
        "inputs": inputs,
        "outputs": outputs,
        "sha256": hashlib.sha256(text.encode()).hexdigest(),
        "bytes": len(text),
    }


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--models",
        default="pi_mlp,conv,conv32,pi_mlp_wide",
        help="comma-separated subset of: " + ",".join(M.MODELS),
    )
    ap.add_argument("--modes", default=",".join(MODES))
    ap.add_argument(
        "--units", type=int, default=128, help="pi_mlp hidden units (ablation: 256)"
    )
    ap.add_argument(
        "--elementwise",
        choices=["jnp", "pallas"],
        default="jnp",
        help="standalone quantize-hook implementation: 'jnp' fuses into XLA "
        "(CPU default, ~5x faster artifacts); 'pallas' runs the L1 kernel at "
        "every hook (TPU shape / kernel-parity testing). The fused maxout "
        "Pallas kernel is always on the hot path either way.",
    )
    # Legacy single-file mode kept for the original scaffold Makefile.
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()

    out_dir = args.out_dir if args.out is None else os.path.dirname(args.out) or "."
    os.makedirs(out_dir, exist_ok=True)

    manifest = {"version": 1, "models": {}, "artifacts": {}}

    manifest["elementwise_impl"] = args.elementwise
    for name in args.models.split(","):
        if name == "pi_mlp":
            m = M.pi_mlp(units=args.units)
        else:
            m = M.MODELS[name]()
        m.elementwise = args.elementwise
        manifest["models"][m.name] = build_model_entry(m)
        # the wide ablation model only needs the fixed-mode artifacts
        modes = ["fixed"] if name == "pi_mlp_wide" else args.modes.split(",")
        for mode in modes:
            for graph in ("train", "eval"):
                lower_artifact(m, mode, graph, out_dir, manifest)

    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote {mpath} ({len(manifest['artifacts'])} artifacts)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
