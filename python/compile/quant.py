"""L2 quantization plumbing: scaling-factor groups, hooks, dropout PRNG.

A `Q` object is threaded through the model's forward and backward passes.
Every call `q(x, layer, kind)` quantizes `x` with the runtime (step, maxv)
scalars of its (layer, kind) scaling-factor group and accumulates that
group's overflow counters; the train step returns the stacked
f32[n_groups, 3] counter matrix that feeds the rust dynamic fixed point
controller (paper section 5).

Two modes share one code path:

  mode="fixed"  -- parameterised fixed point quantization via the Pallas
                   kernel.  step==0 per group means float32 passthrough, so
                   the same compiled artifact serves the float32 baseline,
                   static fixed point (all groups share one scale) and
                   dynamic fixed point (per-group scales fed by rust).
  mode="half"   -- IEEE float16 round-trip at the same hook points
                   (paper Table 3, "Half precision floating point" row).
                   Counters stay zero except n_total.
  mode="off"    -- pure passthrough with NO Pallas calls: the float32
                   reference graph.  Differentiable end to end, used by
                   tests to check the manual backprop against jax.grad.

Dropout (paper section 8.1, following Goodfellow et al. 2013) must live
*inside* the compiled step but be driven by the rust coordinator, so masks
come from a counter-based hash PRNG keyed on a per-step seed scalar: no
jax.random state threading, fully deterministic given (seed, call-site
salt), and cheap elementwise integer ops in HLO.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import formats
from .kernels import ref
from .kernels.quantize import quantize_with_stats


class Q:
    """Per-train-step quantization context.

    steps/maxvs: f32[n_groups] runtime inputs.
    stats are accumulated per group across all call sites that touch the
    group (e.g. several bwd sites quantize into the same dz group).

    `elementwise` picks the implementation of the standalone quantize
    hooks (the fused maxout kernel is controlled separately by layers.py):

      "jnp"    -- pure-jnp reference ops. XLA fuses these into the
                  surrounding computation, so on the CPU PJRT backend the
                  hooks are nearly free. This is the CPU-artifact default
                  (EXPERIMENTS.md §Perf: 62ms -> 11ms per pi_mlp step).
      "pallas" -- the L1 Pallas kernel at every hook. What a real TPU
                  build uses (the kernel fuses the overflow-counter
                  reduction into the store); under interpret=True on CPU
                  each call costs a while-loop round trip, so only enable
                  for kernel-parity testing.

    Both implement the identical contract (pytest asserts bit-equality).
    """

    def __init__(self, steps, maxvs, mode: str, n_layers: int,
                 elementwise: str = "jnp"):
        assert mode in ("fixed", "half", "off"), mode
        assert elementwise in ("jnp", "pallas"), elementwise
        self.steps = steps
        self.maxvs = maxvs
        self.mode = mode
        self.elementwise = elementwise
        self.n_groups = formats.n_groups(n_layers)
        self._stats = [None] * self.n_groups

    def _accumulate(self, g: int, stats):
        if self._stats[g] is None:
            self._stats[g] = stats
        else:
            self._stats[g] = self._stats[g] + stats

    def __call__(self, x, layer: int, kind: int, record: bool = True):
        """Quantize `x` as group (layer, kind); returns the quantized value.

        record=False quantizes on the group's grid without contributing to
        its overflow counters (used for momentum buffers, which share the
        parameter storage format but would skew the controller's statistics
        for the weights themselves -- see DESIGN.md).
        """
        g = formats.group_index(layer, kind)
        if self.mode == "off":
            return x
        if self.mode == "half":
            y = ref.half_roundtrip_ref(x)
            stats = jnp.stack(
                [jnp.float32(0.0), jnp.float32(0.0), jnp.float32(x.size)]
            )
        elif self.elementwise == "pallas":
            y, stats = quantize_with_stats(x, self.steps[g], self.maxvs[g])
        else:
            y, stats = ref.quantize_with_stats_ref(x, self.steps[g], self.maxvs[g])
        if record:
            self._accumulate(g, stats)
        return y

    def scale(self, layer: int, kind: int):
        """(step, maxv) runtime scalars for a group (for fused kernels that
        quantize internally, e.g. the maxout dense kernel)."""
        g = formats.group_index(layer, kind)
        if self.mode in ("half", "off"):
            # The fused kernel only supports grid quantization; in these
            # modes callers use the reference path instead (see layers.py).
            return jnp.float32(0.0), jnp.float32(0.0)
        return self.steps[g], self.maxvs[g]

    def record(self, layer: int, kind: int, stats):
        """Record counters produced by a fused kernel for (layer, kind)."""
        self._accumulate(formats.group_index(layer, kind), stats)

    def stats_matrix(self):
        """f32[n_groups, 3] accumulated (n_over, n_half, n_total)."""
        zero = jnp.zeros((3,), jnp.float32)
        rows = [zero if s is None else s for s in self._stats]
        return jnp.stack(rows)


# ---------------------------------------------------------------------------
# Counter-based hash PRNG for dropout masks.
# ---------------------------------------------------------------------------

_GOLDEN = jnp.uint32(0x9E3779B9)


def _hash_u32(x):
    """lowbias32 finalizer (Wang/Mulvey-style avalanche hash)."""
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x7FEB352D)
    x = x ^ (x >> 15)
    x = x * jnp.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return x


def hash_uniform(shape, seed, salt: int):
    """Deterministic U[0,1) noise tensor.

    seed: runtime f32 scalar holding an integer in [0, 2^24) (the rust
    coordinator increments it every step); salt: static per-call-site
    constant so distinct masks within one step decorrelate.
    """
    n = 1
    for d in shape:
        n *= int(d)
    idx = jax.lax.iota(jnp.uint32, n)
    s = seed.astype(jnp.uint32) if hasattr(seed, "astype") else jnp.uint32(seed)
    x = _hash_u32(idx * _GOLDEN + s * jnp.uint32(0x85EBCA6B) + jnp.uint32(salt))
    u = x.astype(jnp.float32) * jnp.float32(1.0 / 4294967296.0)
    return u.reshape(shape)


def dropout(x, rate, seed, salt: int):
    """Inverted dropout with runtime rate scalar (rate==0 -> identity).

    mask scales by 1/(1-rate) so eval needs no rescaling; a rate of exactly
    zero short-circuits through jnp.where (both branches computed, selection
    is elementwise -- cheap, branch-free HLO).
    """
    u = hash_uniform(x.shape, seed, salt)
    keep = jnp.where(u >= rate, jnp.float32(1.0), jnp.float32(0.0))
    scale = jnp.float32(1.0) / jnp.maximum(jnp.float32(1.0) - rate, jnp.float32(1e-6))
    dropped = x * keep * scale
    return jnp.where(rate > 0, dropped, x), keep


def dropout_bwd(g, keep, rate):
    """Backward of `dropout` given the stored keep mask."""
    scale = jnp.float32(1.0) / jnp.maximum(jnp.float32(1.0) - rate, jnp.float32(1e-6))
    return jnp.where(rate > 0, g * keep * scale, g)
